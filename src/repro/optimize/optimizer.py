"""The detect→transform→verify loop: propose inverse rewrites for a
wasteful program and verify them with the differential pipeline itself.

:func:`optimize` is the entry point.  Given a wasteful callable (and
optionally the :class:`~repro.core.diagnose.Diagnosis` that flagged it), it

1. captures the target through the session (content-addressed, priced),
2. replays the target's jaxpr under each applicable inverse rewrite
   (``repro.optimize.rewrites``), retracing + DCE-ing a candidate callable
   per rewrite — the diagnosed subkind's inverse is proposed first,
3. re-captures every candidate with the *same* functional-equivalence gate
   the detector uses (``gate_against`` the target capture — a candidate
   that changes the answer is rejected, not reported),
4. ranks target + surviving candidates with ``Session.rank`` at N≫2 and
   emits a :class:`~repro.optimize.patch.PatchReport` whose win margins
   come from the session's energy backend.

The verification gates are exactly the detector's own: a rewrite is never
trusted because the pattern matched — only because the rewritten program
computed the same answer and priced cheaper under the session backend.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping, Sequence

import jax

from repro.core.diagnose import Diagnosis
from repro.optimize.engine import build_candidate
from repro.optimize.patch import PatchCandidate, PatchReport
from repro.optimize.rewrites import REWRITES, rewrites_for


def propose(closed, example_args: Sequence[Any], *,
            subkind: str | None = None,
            rewrite_names: Sequence[str] | None = None,
            target_name: str = "target"
            ) -> list[tuple[Any, Callable | None, int, str | None]]:
    """Build rewrite candidates for a captured jaxpr, without verifying.

    Returns ``(rule, candidate, sites, error)`` per attempted rewrite:
    ``candidate`` is None when the rewrite found no site (``sites == 0``)
    or the rewritten program failed to retrace (``error`` holds why).
    """
    names = list(rewrite_names) if rewrite_names is not None \
        else rewrites_for(subkind)
    out = []
    for rname in names:
        rule = REWRITES[rname]()
        try:
            cand, sites = build_candidate(
                closed, rule, example_args,
                name=f"{target_name}__fix_{rname}")
        except Exception as e:   # a broken candidate is a result, not a crash
            out.append((rule, None, 0, f"{type(e).__name__}: {e}"))
            continue
        out.append((rule, cand, sites, None))
    return out


def optimize(fn: Callable, example_args: Sequence[Any], *,
             session=None,
             name: str | None = None,
             diagnosis: Diagnosis | None = None,
             subkind: str | None = None,
             rewrite_names: Sequence[str] | None = None,
             output_rtol: float | None = None,
             config: Mapping[str, Any] | None = None) -> PatchReport:
    """Propose, verify, and rank inverse rewrites for a wasteful program.

    ``diagnosis`` (or a bare ``subkind``) orients the proposal: the
    diagnosed class's inverse is tried first, the remaining rewrites ride
    along as extra rank columns.  ``output_rtol`` overrides the
    per-rewrite functional-equivalence tolerance (bf16 rewrites default
    looser — see ``Rewrite.verify_rtol``).
    """
    from repro.core.session import Session

    session = session or Session()
    example_args = tuple(example_args)
    target_name = name or getattr(fn, "__name__", "target")

    target = session.capture(fn, example_args, name=target_name,
                             config=config)
    closed = target.graph.closed_jaxpr
    if closed is None:
        raise ValueError(f"target {target_name!r} has no captured jaxpr "
                         "(loaded sketch-only artifact?); optimize needs "
                         "a live capture")
    if subkind is None and diagnosis is not None:
        subkind = diagnosis.subkind

    proposals = propose(closed, example_args, subkind=subkind,
                        rewrite_names=rewrite_names,
                        target_name=target_name)

    candidates: list[PatchCandidate] = []
    survivors = []               # (PatchCandidate, CandidateArtifact)
    for rule, cand, sites, error in proposals:
        entry = PatchCandidate(rewrite=rule.name, inverts=rule.name,
                               status="inapplicable", sites=sites)
        if error is not None:
            entry.status = "failed"
            entry.reason = error
        elif sites == 0:
            entry.reason = rule.skip_summary()
        else:
            rtol = output_rtol if output_rtol is not None else rule.verify_rtol
            try:
                art = session.capture(cand, example_args,
                                      name=cand.__name__,
                                      gate_against=target,
                                      output_rtol=rtol, config=config)
            except ValueError as e:
                entry.status = "rejected"
                entry.reason = str(e)
            except Exception as e:
                entry.status = "failed"
                entry.reason = f"{type(e).__name__}: {e}"
            else:
                entry.energy_j = art.total_energy_j
                entry.key = art.key
                entry.win_j = target.total_energy_j - art.total_energy_j
                entry.win_pct = (entry.win_j / target.total_energy_j * 100.0
                                 if target.total_energy_j > 0 else 0.0)
                entry.status = "verified" if entry.win_j > 0 else "no_win"
                survivors.append((entry, art))
        candidates.append(entry)

    report = PatchReport(target=target_name, target_key=target.key,
                         target_energy_j=target.total_energy_j,
                         subkind=subkind, candidates=candidates,
                         diagnosis=diagnosis,
                         meta={"backend": session.backend.name
                               if hasattr(session.backend, "name") else None,
                               "n_proposed": len(proposals),
                               "n_verified": sum(
                                   1 for c in candidates
                                   if c.status == "verified"),
                               # candidate verification re-captures through
                               # the session, so single-block rewrites of a
                               # block-structured target replay only the
                               # rewritten block (core/block_cache.py)
                               "block_cache":
                                   session.block_cache_counters})

    # N-way rank: target + every gate-surviving candidate.  Pairwise
    # candidate-candidate compares may see up to 2x the per-candidate
    # tolerance (triangle inequality through the target), so widen.
    if survivors:
        rank_rtol = 2.0 * max(
            output_rtol if output_rtol is not None
            else REWRITES[e.rewrite]().verify_rtol
            for e, _ in survivors)
        try:
            rank = session.rank([target] + [a for _, a in survivors],
                                output_rtol=rank_rtol)
            report.meta["rank_matrix"] = {
                "names": rank.names,
                "total_energy_j": rank.total_energy_j,
                "waste_matrix": rank.waste_matrix,
                "identical_pairs": rank.meta.get("identical_pairs", 0),
            }
        except Exception as e:   # rank is reporting sugar, not a gate
            report.meta["rank_error"] = f"{type(e).__name__}: {e}"

    report.sort()
    return report
