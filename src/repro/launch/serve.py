"""Serving launcher: batched generation with the continuous-batching engine.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --requests 8 --max-new 12 --energy-audit

Always-on sampled auditing against a fleet store (docs/serving.md):
  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --audit-sample-every 8 --store file:///tmp/fleet
  PYTHONPATH=src python -m repro.cli fleet status --store file:///tmp/fleet
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gpt2-small")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--batch-size", type=int, default=4)
    p.add_argument("--attn-impl", default="xla")
    p.add_argument("--energy-audit", action="store_true")
    p.add_argument("--audit-timeout", type=float, default=None,
                   help="wall-clock budget (s) for one energy audit before "
                        "the watchdog abandons it (default: engine config)")
    p.add_argument("--audit-breaker-threshold", type=int, default=3,
                   help="consecutive audit failures before the circuit "
                        "breaker disables further audits")
    # always-on sampled auditing (repro.audit)
    p.add_argument("--store", default=None,
                   help="fleet store URI (path, file:// or writable "
                        "http(s)://) for live-audit captures, goldens and "
                        "audit logs")
    p.add_argument("--audit-sample-every", type=int, default=0,
                   help="audit every Nth observation of each request class "
                        "(0 = sampled auditing off)")
    p.add_argument("--audit-slo-ms", type=float, default=None,
                   help="latency SLO (ms): sampled audits only run when the "
                        "observed step latency leaves headroom under it")
    p.add_argument("--engine-id", default=None,
                   help="stable engine identity in the fleet store "
                        "(default: <arch>-<pid>)")
    p.add_argument("--mutate-decode", default=None,
                   help="demo/chaos: audit the decode probe through a named "
                        "waste mutation (repro.testing.mutate) so drift "
                        "alarms fire against the healthy fleet golden")
    p.add_argument("--health-json", action="store_true",
                   help="print engine.health() as JSON after serving")
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    assert cfg.is_causal, f"{args.arch} is encoder-only; nothing to decode"

    params = tf.model_init(cfg, jax.random.key(0))
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    ecfg = EngineConfig(
        batch_size=args.batch_size,
        max_len=args.prompt_len + args.max_new + 8,
        attn_impl=args.attn_impl,
        audit_breaker_threshold=args.audit_breaker_threshold,
        store=args.store,
        audit_sample_every=args.audit_sample_every,
        audit_slo_ms=args.audit_slo_ms,
        engine_id=args.engine_id,
        audit_mutate_decode=args.mutate_decode)
    if args.audit_timeout is not None:
        ecfg.audit_timeout_s = args.audit_timeout
    engine = ServeEngine(cfg, params, mesh=mesh, ecfg=ecfg)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    toks = engine.stats["tokens_generated"]
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print("stats:", {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in engine.stats.items()})
    s = engine.stats
    print(f"audit-health: calls={s['audit_calls']} ok={s['audit_ok']} "
          f"failures={s['audit_failures']} timeouts={s['audit_timeouts']} "
          f"skipped={s['audit_skipped']} sampled={s['audit_sampled']} "
          f"alarms={s['audit_alarms']} "
          f"breaker_open={s['audit_breaker_open']}")
    if engine.auditor is not None:
        a = engine.auditor.summary()
        print(f"live audit: {len(a['classes'])} request classes "
              f"({', '.join(a['classes'])}), {a['sampled']}/{a['observed']} "
              f"sampled, {a['alarms']} drift alarms, "
              f"{a['flush_failures']} flush failures")
        for alarm in engine.auditor.alarms:
            print(f"  DRIFT {alarm.class_key}: {alarm.energy_delta:+.1%} "
                  f"kind={alarm.diagnosis_kind} "
                  + ("[degraded] " if alarm.degraded else "")
                  + f"- {alarm.detail}")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.generated}")
    if args.health_json:
        print(json.dumps(engine.health(), indent=2, sort_keys=True))

    if args.energy_audit:
        # error-bounded audit: a broken/hung profiler reports its failure
        # and leaves the serving results above intact
        report = engine.audit(prompt_len=args.prompt_len,
                              timeout_s=args.audit_timeout)
        if report is not None:
            print(report.render())
        else:
            print("energy audit unavailable: "
                  f"{engine.stats.get('audit_last_error', 'breaker open')} "
                  f"(failures={engine.stats['audit_failures']}, "
                  f"timeouts={engine.stats['audit_timeouts']}, "
                  f"breaker_open={engine.stats['audit_breaker_open']})")


if __name__ == "__main__":
    main()
