"""Serving launcher: batched generation with the continuous-batching engine.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --requests 8 --max-new 12 --energy-audit
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--batch-size", type=int, default=4)
    p.add_argument("--attn-impl", default="xla")
    p.add_argument("--energy-audit", action="store_true")
    p.add_argument("--audit-timeout", type=float, default=None,
                   help="wall-clock budget (s) for one energy audit before "
                        "the watchdog abandons it (default: engine config)")
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    assert cfg.is_causal, f"{args.arch} is encoder-only; nothing to decode"

    params = tf.model_init(cfg, jax.random.key(0))
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    engine = ServeEngine(cfg, params, mesh=mesh,
                         ecfg=EngineConfig(
                             batch_size=args.batch_size,
                             max_len=args.prompt_len + args.max_new + 8,
                             attn_impl=args.attn_impl))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    toks = engine.stats["tokens_generated"]
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print("stats:", {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in engine.stats.items()})
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.generated}")

    if args.energy_audit:
        # error-bounded audit: a broken/hung profiler reports its failure
        # and leaves the serving results above intact
        report = engine.audit(prompt_len=args.prompt_len,
                              timeout_s=args.audit_timeout)
        if report is not None:
            print(report.render())
        else:
            print("energy audit unavailable: "
                  f"{engine.stats.get('audit_last_error', 'breaker open')} "
                  f"(failures={engine.stats['audit_failures']}, "
                  f"timeouts={engine.stats['audit_timeouts']}, "
                  f"breaker_open={engine.stats['audit_breaker_open']})")


if __name__ == "__main__":
    main()
