import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, from the compiled per-device SPMD module:
  * memory_analysis  — bytes/device (args, temps, peak): proves it fits;
  * cost_analysis    — per-device HLO FLOPs and HBM bytes;
  * collective bytes — regex over the post-scheduling HLO, summing operand
                       sizes of all-gather/all-reduce/reduce-scatter/
                       all-to-all/collective-permute, split ICI vs DCN
                       (a collective whose replica group crosses the 256-chip
                       pod boundary moves at DCN, not ICI, bandwidth);
  * roofline terms   — compute/memory/collective seconds + dominant term
                       (EXPERIMENTS.md §Roofline reads these JSONs).

Scan-body correction: XLA's cost_analysis does NOT multiply while-loop body
costs by the trip count, so each cell also compiles ONE superblock segment
under the same shardings and totals  full + (num_superblocks - 1) * segment
(DESIGN.md §7).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k \
      --mesh single --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES, get_config, list_archs, supported_shapes
from repro.hw.specs import TPU_V5E
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_bundle, superblock_segment

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

# result-side instruction: "%name = <shapes> <op>(...), ..."
_COLL_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _shape_bytes(text: str) -> float:
    b = 0.0
    for sm in _SHAPE_RE.finditer(text):
        n = 1
        if sm.group(2):
            for d in sm.group(2).split(","):
                n *= int(d)
        b += n * _DTYPE_BYTES[sm.group(1)]
    return b


def _first_group(tail: str) -> list[int] | None:
    """Device ids of the LAST replica group (iota groups may be uniform but
    the later groups are the ones that cross pod boundaries first)."""
    gm = _GROUPS_LIST_RE.search(tail)
    if gm:
        return [int(x) for x in gm.group(1).split(",")]
    gm = _GROUPS_IOTA_RE.search(tail)
    if gm:
        g, s = int(gm.group(1)), int(gm.group(2))
        dims = [int(x) for x in gm.group(3).split(",")]
        ids = np.arange(int(np.prod(dims)))
        if gm.group(4):
            perm = [int(x) for x in gm.group(4).split(",")]
            ids = ids.reshape(dims).transpose(perm).reshape(-1)
        groups = ids.reshape(g, s)
        return list(groups[-1])
    return None


def collective_bytes(hlo_text: str, *, pod_size: int = 256) -> dict:
    """Per-device interconnect bytes from the post-SPMD HLO module.

    Wire-byte model per op (ring algorithms, R = result bytes, gs = group
    size): all-gather R*(gs-1)/gs; all-reduce 2*R*(gs-1)/gs; reduce-scatter
    R*(gs-1); all-to-all R*(gs-1)/gs; collective-permute R.
    A collective whose replica group spans two pods (id // pod_size differs)
    is classed as DCN traffic.
    Returns {"ici": bytes, "dcn": bytes, "ops": {opname: count}}.
    """
    ici = 0.0
    dcn = 0.0
    ops: dict[str, int] = {}
    for m in _COLL_LINE_RE.finditer(hlo_text):
        shapes, opname = m.group(1), m.group(2)
        r = _shape_bytes(shapes)
        tail = hlo_text[m.end():m.end() + 4000]
        group = _first_group(tail)
        gs = len(group) if group else 1
        if gs <= 1:
            continue                      # degenerate / single-device group
        if opname == "all-gather":
            b = r * (gs - 1) / gs
        elif opname == "all-reduce":
            b = 2.0 * r * (gs - 1) / gs
        elif opname == "reduce-scatter":
            b = r * (gs - 1)
        elif opname == "all-to-all":
            b = r * (gs - 1) / gs
        else:                             # collective-permute
            b = r
        ops[opname] = ops.get(opname, 0) + 1
        crosses = group is not None and len({i // pod_size for i in group}) > 1
        if crosses:
            dcn += b
        else:
            ici += b
    return {"ici": ici, "dcn": dcn, "ops": ops}


def _compile_bundle(bundle, mesh):
    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     donate_argnums=bundle.donate_argnums)
    t0 = time.time()
    lowered = jitted.lower(*bundle.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jaxlib < 0.5: one dict per program
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {k: int(getattr(ma, k)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes")}
    # jaxlib < 0.5 has no peak_memory_in_bytes; args + outputs + temps -
    # aliased is the live-set upper bound XLA reports as peak on newer
    # releases.
    peak = getattr(ma, "peak_memory_in_bytes", None)
    if peak is None:
        peak = (out["argument_size_in_bytes"] + out["output_size_in_bytes"]
                + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
    out["peak_memory_in_bytes"] = int(peak)
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1           # one decode token per sequence
    return 2.0 * n * tokens


def roofline(per_dev: dict, mesh_devices: int, spec=TPU_V5E) -> dict:
    """Three roofline terms (seconds, per step) from per-device totals."""
    t_compute = per_dev["flops"] / spec.peak_flops_bf16
    t_memory = per_dev["bytes"] / spec.hbm_bw
    ici_bw = spec.ici_bw_per_link * spec.ici_links
    t_coll = per_dev["coll_ici"] / ici_bw + per_dev["coll_dcn"] / spec.dcn_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=lambda k: terms[k])
    terms["dominant"] = dom
    terms["step_s"] = max(t_compute, t_memory, t_coll)
    return terms


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             attn_impl: str = "xla", skip_segment: bool = False,
             tcfg_overrides: dict | None = None,
             sharding_preset: str = "global-fsdp") -> dict:
    from repro.sharding.rules import set_sharding_preset
    set_sharding_preset(sharding_preset)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(list(mesh.shape.values())))
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "devices": ndev, "attn_impl": attn_impl,
                 "sharding_preset": sharding_preset}
    t_start = time.time()

    kw = {}
    if shape.kind == "train":
        overrides = dict(tcfg_overrides or {})
        overrides.setdefault("attn_impl", attn_impl)
        from repro.train.train_step import TrainConfig
        kw["tcfg"] = TrainConfig(**overrides)
    else:
        kw["attn_impl"] = attn_impl

    bundle = make_bundle(cfg, shape, mesh, **kw)
    compiled, t_lower, t_compile = _compile_bundle(bundle, mesh)
    rec["t_lower_s"] = round(t_lower, 1)
    rec["t_compile_s"] = round(t_compile, 1)
    rec["memory"] = _memory_dict(compiled)
    full_cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    full_coll = collective_bytes(hlo)
    del hlo

    nsb = cfg.num_superblocks
    if not skip_segment and nsb > 1:
        seg = superblock_segment(cfg, shape, mesh,
                                 train=(shape.kind == "train"),
                                 attn_impl=attn_impl,
                                 remat=(tcfg_overrides or {}).get("remat", True)
                                 if shape.kind == "train" else True)
        seg_compiled, _, seg_t = _compile_bundle(seg, mesh)
        seg_cost = _cost_dict(seg_compiled)
        seg_hlo = seg_compiled.as_text()
        seg_coll = collective_bytes(seg_hlo)
        del seg_hlo
        rec["t_segment_compile_s"] = round(seg_t, 1)
        rec["segment"] = {"flops": seg_cost["flops"],
                          "bytes": seg_cost["bytes"],
                          "coll_ici": seg_coll["ici"],
                          "coll_dcn": seg_coll["dcn"]}
        k = nsb - 1
        per_dev = {
            "flops": full_cost["flops"] + k * seg_cost["flops"],
            "bytes": full_cost["bytes"] + k * seg_cost["bytes"],
            "coll_ici": full_coll["ici"] + k * seg_coll["ici"],
            "coll_dcn": full_coll["dcn"] + k * seg_coll["dcn"],
        }
    else:
        per_dev = {"flops": full_cost["flops"], "bytes": full_cost["bytes"],
                   "coll_ici": full_coll["ici"], "coll_dcn": full_coll["dcn"]}

    rec["per_device"] = per_dev
    rec["collective_ops"] = full_coll["ops"]
    rec["roofline"] = roofline(per_dev, ndev)
    mf = model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    hlo_global = per_dev["flops"] * ndev
    rec["model_flops_ratio"] = mf / hlo_global if hlo_global else 0.0
    # useful-compute fraction of the step (the §Perf score numerator)
    step_s = rec["roofline"]["step_s"]
    ideal_s = mf / ndev / TPU_V5E.peak_flops_bf16
    rec["roofline_fraction"] = ideal_s / step_s if step_s > 0 else 0.0
    rec["t_total_s"] = round(time.time() - t_start, 1)
    rec["status"] = "ok"
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None)
    p.add_argument("--mesh", choices=("single", "multi", "both"),
                   default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--attn-impl", default="xla")
    p.add_argument("--sharding-preset", default="global-fsdp",
                   choices=("global-fsdp", "pod-fsdp"))
    p.add_argument("--remat", default=None, choices=("full", "dots", "none"))
    p.add_argument("--skip-segment", action="store_true")
    p.add_argument("--microbatches", type=int, default=None)
    p.add_argument("--out", type=str, default="results/dryrun")
    args = p.parse_args()

    cells: list[tuple[str, str]] = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        cfg = get_config(a)
        shapes = ([args.shape] if args.shape else supported_shapes(cfg))
        for s in shapes:
            cells.append((a, s))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    tcfg_overrides = {}
    if args.microbatches:
        tcfg_overrides["microbatches"] = args.microbatches
    if args.remat:
        tcfg_overrides["remat"] = {"full": True, "none": False,
                                   "dots": "dots"}[args.remat]
    tcfg_overrides = tcfg_overrides or None
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            tag = f"{arch}__{shape}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, multi, attn_impl=args.attn_impl,
                               skip_segment=args.skip_segment,
                               tcfg_overrides=tcfg_overrides,
                               sharding_preset=args.sharding_preset)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("status") == "ok":
                r = rec["roofline"]
                print(f"[ ok ] {tag}: peak={rec['memory']['peak_memory_in_bytes']/2**30:.2f} GiB/dev  "
                      f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
                      f"frac={rec['roofline_fraction']:.3f}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
