"""Dtype-faithful analytic cost of a cell, from the step jaxpr.

Why this exists: the dry-run's compiled numbers come from the XLA *CPU*
pipeline, whose FloatNormalization pass rewrites every bf16 tensor to f32
before buffer assignment — so ``cost_analysis()['bytes accessed']`` prices
bf16 traffic at 4 bytes and cannot see dtype-level optimizations (bf16
attention scores, bf16 gradient reduction).  This module prices the SAME
step with core/costs.py operator rules, which read the true jaxpr dtypes
(scan bodies multiplied by trip count, collectives priced in ici_bytes).

Used by §Perf as the second meter next to the compiled-artifact numbers:
structural changes are validated on both meters; dtype changes on this one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.core.costs import graph_cost
from repro.core.graph import trace
from repro.launch.specs import batch_specs
from repro.models import transformer as tf
from repro.train.optimizer import OptimizerConfig, abstract_opt_state
from repro.train.train_step import TrainConfig, make_train_step


def analytic_cell_cost(arch: str, shape_name: str, *,
                       attn_impl: str = "xla",
                       devices: int = 256,
                       remat: bool = True) -> dict:
    """Global flops/bytes/ici of one (arch x shape) step, divided by the
    device count under the uniform-sharding assumption."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tcfg = TrainConfig(attn_impl=attn_impl, remat=remat)
        opt_cfg = OptimizerConfig()
        step = make_train_step(cfg, None, opt_cfg, tcfg)
        params = tf.model_abstract_params(cfg)
        opt = abstract_opt_state(params, opt_cfg)
        batch = batch_specs(cfg, shape)
        closed = jax.make_jaxpr(step)(params, opt, batch)
    elif shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len

        def fn(params, tokens):
            return tf.prefill(cfg, params, tokens, max_len=S,
                              attn_impl=attn_impl)[0]
        params = tf.model_abstract_params(cfg)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        closed = jax.make_jaxpr(fn)(params, tokens)
    else:
        B, S = shape.global_batch, shape.seq_len

        def fn(params, caches, tokens, pos):
            return tf.decode_step(cfg, params, caches, tokens, pos,
                                  attn_impl=attn_impl)[0]
        params = tf.model_abstract_params(cfg)
        caches = tf.abstract_cache(cfg, B, S)
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        closed = jax.make_jaxpr(fn)(params, caches, tokens, pos)

    from repro.core.graph import extract_graph
    g = extract_graph(closed, name=f"{arch}/{shape_name}")
    c = graph_cost(g)
    return {"flops": c.flops / devices, "bytes": c.hbm_bytes / devices,
            "ici_bytes": c.ici_bytes / devices,
            "global_flops": c.flops, "global_bytes": c.hbm_bytes}
