"""Training launcher.

Drives train/loop.py with an arch + shape from the registry.  On the CPU
container, ``--smoke`` selects the reduced config and a small batch so the
loop actually steps; on real hardware the full config trains on the
production mesh.  ``--energy-audit`` runs the Magneton differential debugger
over the model's own forward pass before training starts — the paper's
profiler wired in as a launcher feature.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --energy-audit
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig
from repro.train.checkpoint import PreemptionGuard


def energy_audit(cfg, *, store: str | None = None) -> None:
    """Differential audit: the model's unfused GELU/attention twins.

    Session-based; pass ``store`` (CLI: ``--audit-store DIR``) to make the
    captures content-addressed, so re-running the audit on later launches
    hits the store instead of re-executing the instrumented pipeline.
    """
    from repro.core.session import Session
    from repro.zoo.cases import get_case
    print("=== Magneton energy audit (launcher feature) ===")
    session = Session(store=store)
    for cid in ("n1-gelu-backend", "c13-ce-onehot", "c4-gqa-repeat"):
        c = get_case(cid)
        art_cur = session.capture(c.inefficient, c.make_args(),
                                  name=c.id + "-current", config=c.config_a)
        art_fix = session.capture(c.efficient, c.make_args(),
                                  name=c.id + "-fix", config=c.config_b)
        print(session.compare(art_cur, art_fix,
                              output_rtol=c.output_rtol).render())


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + tiny batch (CPU containers)")
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seq-len", type=int, default=None)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    p.add_argument("--checkpoint-every", type=int, default=25)
    p.add_argument("--energy-audit", action="store_true")
    p.add_argument("--audit-store", default=None,
                   help="artifact store dir for the energy audit (cache "
                        "hits across launches)")
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--attn-impl", default="xla", choices=("xla", "pallas"))
    p.add_argument("--metrics-out", default=None)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]
    if args.smoke:
        shape = ShapeConfig("smoke", seq_len=args.seq_len or 64,
                            global_batch=args.batch or 8, kind="train")
    elif args.batch or args.seq_len:
        shape = ShapeConfig(shape.name, seq_len=args.seq_len or shape.seq_len,
                            global_batch=args.batch or shape.global_batch,
                            kind="train")

    if args.energy_audit:
        energy_audit(cfg, store=args.audit_store)

    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    guard = PreemptionGuard()
    result = run_training(
        cfg, shape, mesh=mesh,
        opt_cfg=OptimizerConfig(total_steps=args.steps,
                                warmup_steps=max(2, args.steps // 10),
                                compress_grads=args.compress_grads),
        tcfg=TrainConfig(microbatches=args.microbatches,
                         attn_impl=args.attn_impl),
        loop=LoopConfig(num_steps=args.steps,
                        checkpoint_every=args.checkpoint_every,
                        checkpoint_dir=args.checkpoint_dir),
        guard=guard)
    print(f"finished at step {result['final_step']}  "
          f"final loss {result['history'][-1]['loss']:.4f}  "
          f"early-exit={result['exited_early']}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"history": result["history"],
                       "straggler_events": result["straggler_events"]}, f)


if __name__ == "__main__":
    main()
