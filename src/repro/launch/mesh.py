"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.  The single-pod production mesh is 16x16
(256 chips, "data" x "model"); the multi-pod mesh is (2,16,16) with the
leading "pod" axis crossing the data-center network.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit axis types; older releases predate them
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None) -> Mesh:
    """A small mesh over whatever devices exist (CPU tests, smoke runs)."""
    n = len(jax.devices())
    model = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return _make_mesh((data, model), ("data", "model"))


def mesh_num_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
