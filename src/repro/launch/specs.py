"""Abstract input specs and sharded step builders for the dry-run.

Everything here operates on ShapeDtypeStructs — weak-type-correct, shardable,
zero device allocation — so the full production configs (up to 398B params,
512k contexts) lower and compile on the CPU container.

This module must stay importable WITHOUT the 512-device XLA flag; only
launch/dryrun.py sets that, as its first two lines, per the deployment
contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.models import transformer as tf
from repro.sharding.rules import GLOBAL_RULES
from repro.train.optimizer import (OptimizerConfig, abstract_opt_state,
                                   opt_state_shardings)
from repro.train.train_step import TrainConfig, make_train_step


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# input specs (deliverable: ShapeDtypeStruct stand-ins for every model input)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Training-batch ShapeDtypeStructs for one arch x shape."""
    B, S = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {}
    if cfg.family == "audio":
        out["frames"] = _sds((B, S, cfg.d_model), cfg.dtype)
    else:
        out["tokens"] = _sds((B, S), jnp.int32)
    out["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        out["image_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model),
                                   cfg.dtype)
    return out


def input_specs(arch: str, shape_name: str = "train_4k") -> dict[str, Any]:
    """Public API per the deliverable: all model inputs as abstract specs."""
    cfg = get_config(arch)
    return batch_specs(cfg, SHAPES[shape_name])


def batch_shardings(mesh: Mesh, specs: dict[str, Any]) -> dict[str, Any]:
    out = {}
    for k, v in specs.items():
        axes: tuple = ("batch",) + (None,) * (len(v.shape) - 1)
        if k in ("tokens", "labels", "frames") and len(v.shape) >= 2:
            axes = ("batch", "seq") + (None,) * (len(v.shape) - 2)
        out[k] = GLOBAL_RULES.sharding(mesh, axes, v.shape)
    return out


# ---------------------------------------------------------------------------
# step builders (train / prefill / decode), with production shardings
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one cell: fn, abstract args, shardings."""

    fn: Callable
    args: tuple
    in_shardings: tuple
    donate_argnums: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


def train_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                 tcfg: TrainConfig = TrainConfig(),
                 opt_cfg: OptimizerConfig = OptimizerConfig()) -> StepBundle:
    params = tf.model_abstract_params(cfg)
    pshard = tf.model_param_shardings(cfg, mesh)
    opt = abstract_opt_state(params, opt_cfg)
    oshard = opt_state_shardings(pshard, opt_cfg, mesh)
    batch = batch_specs(cfg, shape)
    bshard = batch_shardings(mesh, batch)
    step = make_train_step(cfg, mesh, opt_cfg, tcfg)
    return StepBundle(fn=step, args=(params, opt, batch),
                      in_shardings=(pshard, oshard, bshard),
                      donate_argnums=(0, 1),
                      meta={"kind": "train"})


def _cache_shardings(cfg: ModelConfig, mesh: Mesh, caches) -> Any:
    axes = tf.cache_logical_axes(cfg)
    return jax.tree_util.tree_map(
        lambda leaf, ax: GLOBAL_RULES.sharding(mesh, ax, leaf.shape),
        caches, axes)


def prefill_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                   attn_impl: str = "xla") -> StepBundle:
    B, S = shape.global_batch, shape.seq_len
    params = tf.model_abstract_params(cfg)
    pshard = tf.model_param_shardings(cfg, mesh)

    if cfg.family == "audio":
        frames = _sds((B, S, cfg.d_model), cfg.dtype)
        fshard = GLOBAL_RULES.sharding(mesh, ("batch", "seq_sp", None),
                                       frames.shape)

        def fn(params, frames):
            logits, _ = tf.forward(cfg, params, None, inputs_embeds=frames,
                                   mesh=mesh, remat=True, attn_impl=attn_impl,
                                   logits_mode="last")
            return logits
        return StepBundle(fn=fn, args=(params, frames),
                          in_shardings=(pshard, fshard),
                          meta={"kind": "prefill"})

    tokens = _sds((B, S), jnp.int32)
    tshard = GLOBAL_RULES.sharding(mesh, ("batch", "seq"), tokens.shape)
    extra_args: tuple = ()
    extra_shard: tuple = ()
    if cfg.family == "vlm":
        img = _sds((B, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
        extra_args = (img,)
        extra_shard = (GLOBAL_RULES.sharding(mesh, ("batch", None, None),
                                             img.shape),)

    def fn(params, tokens, *extra):
        img = extra[0] if extra else None
        logits, caches = tf.prefill(cfg, params, tokens, mesh=mesh,
                                    max_len=S, image_embeds=img,
                                    attn_impl=attn_impl)
        return logits, caches
    return StepBundle(fn=fn, args=(params, tokens) + extra_args,
                      in_shardings=(pshard, tshard) + extra_shard,
                      meta={"kind": "prefill"})


def decode_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                  attn_impl: str = "xla") -> StepBundle:
    """One decode step with a seq_len-deep cache (the assigned decode_* /
    long_* cells lower serve_step, not train_step)."""
    B, S = shape.global_batch, shape.seq_len
    params = tf.model_abstract_params(cfg)
    pshard = tf.model_param_shardings(cfg, mesh)
    caches = tf.abstract_cache(cfg, B, S)
    cshard = _cache_shardings(cfg, mesh, caches)
    tokens = _sds((B, 1), jnp.int32)
    tshard = GLOBAL_RULES.sharding(mesh, ("batch", None), tokens.shape)
    pos = _sds((), jnp.int32)
    posshard = NamedSharding(mesh, P())
    extra_args: tuple = ()
    extra_shard: tuple = ()

    def fn(params, caches, tokens, pos, *extra):
        logits, new_caches = tf.decode_step(cfg, params, caches, tokens, pos,
                                            mesh=mesh, attn_impl=attn_impl)
        return logits, new_caches

    return StepBundle(fn=fn, args=(params, caches, tokens, pos) + extra_args,
                      in_shardings=(pshard, cshard, tshard, posshard)
                      + extra_shard,
                      donate_argnums=(1,),
                      meta={"kind": "decode"})


def make_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                **kw) -> StepBundle:
    if shape.kind == "train":
        return train_bundle(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return prefill_bundle(cfg, shape, mesh, **kw)
    if shape.kind == "decode":
        return decode_bundle(cfg, shape, mesh, **kw)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# segment bundles — one superblock, for the scan-trip-count cost correction
# ---------------------------------------------------------------------------

def superblock_segment(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                       train: bool, attn_impl: str = "xla",
                       remat: bool | str = True) -> StepBundle:
    """fwd(+bwd if train) of ONE superblock under production shardings.

    compiled.cost_analysis() does not multiply while-body costs by the trip
    count, so the roofline total is  full + (num_superblocks-1) * segment.
    """
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    params_one = jax.tree_util.tree_map(
        lambda s: _sds(s.shape[1:], s.dtype),
        tf.model_abstract_params(cfg)["blocks"])
    pshard_one = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, P(*tuple(s.spec)[1:])),
        tf.model_param_shardings(cfg, mesh)["blocks"])
    x = _sds((B, S, cfg.d_model), cfg.dtype)
    xshard = GLOBAL_RULES.sharding(mesh, ("batch", "seq_sp", None), x.shape)
    positions = _sds((B, S), jnp.int32)
    posshard = GLOBAL_RULES.sharding(mesh, ("batch", "seq"), positions.shape)
    img = None
    imgshard: tuple = ()
    if cfg.family == "vlm":
        img = _sds((B, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
        imgshard = (GLOBAL_RULES.sharding(mesh, ("batch", None, None),
                                          img.shape),)

    cache_args: tuple = ()
    cache_shard: tuple = ()
    if shape.kind in ("decode", "prefill"):
        cache_one = jax.tree_util.tree_map(
            lambda s: _sds(s.shape[1:], s.dtype),
            tf.abstract_cache(cfg, B, shape.seq_len))
        axes_one = jax.tree_util.tree_map(
            lambda ax: ax[1:], tf.cache_logical_axes(cfg),
            is_leaf=lambda v: isinstance(v, tuple))
        cshard_one = jax.tree_util.tree_map(
            lambda leaf, ax: GLOBAL_RULES.sharding(mesh, ax, leaf.shape),
            cache_one, axes_one)
        cache_args = (cache_one,)
        cache_shard = (cshard_one,)

    if train:
        def fn(p, x, positions, *extra):
            image = extra[0] if (cfg.family == "vlm" and extra) else None

            def f(p_, x_):
                out, _, aux = tf.superblock_apply(cfg, p_, x_, positions,
                                                  mesh=mesh,
                                                  image_embeds=image,
                                                  attn_impl=attn_impl)
                return out, aux
            # match the train pipeline's remat policy
            if remat == "dots":
                f = jax.checkpoint(
                    f, policy=jax.checkpoint_policies.dots_saveable)
            elif remat:
                f = jax.checkpoint(f)
            (out, aux), vjp = jax.vjp(f, p, x)
            gp, gx = vjp((out, aux))
            return out, gp, gx
        args = (params_one, x, positions) + ((img,) if img is not None else ())
        shards = (pshard_one, xshard, posshard) + imgshard
    else:
        def fn(p, x, positions, *extra):
            idx = 0
            cache = None
            if shape.kind in ("decode", "prefill"):
                cache = extra[idx]
                idx += 1
            image = extra[idx] if (cfg.family == "vlm"
                                   and len(extra) > idx) else None
            out, nc, aux = tf.superblock_apply(
                cfg, p, x, positions, mesh=mesh, cache=cache,
                cache_pos=(positions[0, 0] if shape.kind == "decode"
                           else jnp.int32(0)),
                image_embeds=image, decode=(shape.kind == "decode"),
                attn_impl=attn_impl)
            return out, nc
        args = ((params_one, x, positions) + cache_args
                + ((img,) if img is not None else ()))
        shards = (pshard_one, xshard, posshard) + cache_shard + imgshard

    return StepBundle(fn=fn, args=args, in_shardings=shards,
                      meta={"kind": "segment",
                            "trips": cfg.num_superblocks})
