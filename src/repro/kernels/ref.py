"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose tests (tests/
test_kernels.py) and simultaneously the *energy-wasteful twins* used by the
differential debugger: each oracle materializes intermediates in HBM that the
fused kernel keeps in VMEM, so (ref, kernel) pairs double as zoo cases.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: float | None = None) -> jax.Array:
    """Naive full-matrix attention.  q: (B,H,Sq,D); k,v: (B,KV,Sk,D).

    Materializes the (Sq,Sk) score matrix in HBM — the wasteful twin of the
    flash kernel (zoo case vllm-20174).  GQA via head-group broadcasting.
    """
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    g = H // KV
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, g, Sq, D)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        offset = Sk - Sq
        qpos = jnp.arange(Sq)[:, None] + offset
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """x: (..., d); w: (d,).  fp32 statistics, output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def swiglu(g: jax.Array, u: jax.Array) -> jax.Array:
    """silu(g) * u, elementwise."""
    gf = g.astype(jnp.float32)
    return (gf * jax.nn.sigmoid(gf) * u.astype(jnp.float32)).astype(g.dtype)


def gelu_tanh(x: jax.Array) -> jax.Array:
    """Tanh-approximate GELU, the five-op unfused form (case hf-39073)."""
    xf = x.astype(jnp.float32)
    c = np.sqrt(2.0 / np.pi).astype(np.float32)
    inner = c * (xf + 0.044715 * xf * xf * xf)
    return (0.5 * xf * (1.0 + jnp.tanh(inner))).astype(x.dtype)


# ---------------------------------------------------------------------------
# selective scan (mamba)
# ---------------------------------------------------------------------------

def ssm_scan(a: jax.Array, b: jax.Array, c: jax.Array,
             h0: jax.Array, *, chunk: int = 64) -> tuple[jax.Array, jax.Array]:
    """Fused selective scan oracle.

    Solves h_t = a_t * h_{t-1} + b_t and projects y_t = <h_t, c_t>_n.
    a, b: (B,S,di,n) f32; c: (B,S,n) f32; h0: (B,di,n) f32.
    Returns (y (B,S,di) f32, h_last (B,di,n) f32).

    This oracle materializes all S states in HBM (the wasteful twin); the
    Pallas kernel keeps the state in VMEM and only writes y.
    """
    B, S, di, n = a.shape
    q = min(chunk, S)
    assert S % q == 0
    nc = S // q
    a_c = a.reshape(B, nc, q, di, n).transpose(1, 0, 2, 3, 4)
    b_c = b.reshape(B, nc, q, di, n).transpose(1, 0, 2, 3, 4)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, ab):
        ac, bc = ab
        aa, bb = jax.lax.associative_scan(op, (ac, bc), axis=1)
        h_steps = aa * h[:, None] + bb
        return h_steps[:, -1], h_steps

    h_last, h_all = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_all = h_all.transpose(1, 0, 2, 3, 4).reshape(B, S, di, n)
    y = jnp.einsum("bsen,bsn->bse", h_all, c)
    return y, h_last
