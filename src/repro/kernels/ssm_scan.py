"""Chunked selective-scan Pallas kernel (Mamba recurrence, state in VMEM).

Solves h_t = a_t * h_{t-1} + b_t and fuses the output projection
y_t = <h_t, c_t> over the state dim.  The XLA oracle (ref.ssm_scan and the
model path in models/ssm.py) must materialize every per-step state
h_all (B,S,di,n) in HBM — n x more traffic than the inputs — because the
projection is a separate einsum.  The kernel keeps the running state in a
(block_d x n) VMEM scratch, writes only y (B,S,di), and carries the state
across sequence chunks through the sequential minormost grid dimension.

HBM traffic: oracle O(S*di*n) state writes + reads; kernel O(S*di) outputs.
With n=16 that is a ~16x reduction on the scan stage — the same
"keep-it-in-SRAM" insight as the paper's fused-GELU finding, applied to the
SSM mixer that three of our assigned architectures (xlstm, jamba) depend on.

TPU layout note: blocks arrive as (chunk, block_d, n) with the state dim n
minormost to match the model's (B,S,di,n) layout.  A production v5e kernel
would transpose di into the lane dimension (n=16 < 128 lanes); we keep the
model layout here and record the lever in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _ssm_kernel(a_ref, b_ref, c_ref, h0_ref, y_ref, hlast_ref, h_scr, *,
                chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]              # (block_d, n)
        y_ref[0, t] = jnp.sum(h * c_ref[0, t][None, :], axis=1)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        hlast_ref[0] = h_scr[...]


def ssm_scan_fused(a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array,
                   *, chunk: int = 64, block_d: int = 128,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """a, b: (B,S,di,n) f32; c: (B,S,n) f32; h0: (B,di,n) f32.

    Returns (y (B,S,di) f32, h_last (B,di,n) f32).
    """
    B, S, di, n = a.shape
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0, (S, chunk)
    assert di % block_d == 0, (di, block_d)
    num_chunks = S // chunk
    d_blocks = di // block_d

    kernel = functools.partial(_ssm_kernel, chunk=chunk,
                               num_chunks=num_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, d_blocks, num_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda bi, di_, ci: (bi, ci, di_, 0)),
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda bi, di_, ci: (bi, ci, di_, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di_, ci: (bi, ci, 0)),
            pl.BlockSpec((1, block_d, n), lambda bi, di_, ci: (bi, di_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, di_, ci: (bi, ci, di_)),
            pl.BlockSpec((1, block_d, n), lambda bi, di_, ci: (bi, di_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c, h0)
    return y, h_last
