"""Flash attention for TPU: tiled online-softmax with explicit VMEM blocking.

TPU adaptation of the paper's prefill-attention finding (vllm-20174 /
"default prefill attention can be inefficient"): the efficient implementation
never materializes the (Sq, Sk) score matrix in HBM.  The kernel streams
(block_q x d) query tiles against (block_k x d) key/value tiles held in VMEM,
maintaining the online-softmax running max/denominator in VMEM scratch, and
writes each output tile exactly once.  HBM traffic drops from
O(Sq*Sk + S*d) to O(S*d) — on a 32k prefill that is the difference between
~4 GB and ~17 MB of score traffic per head.

Grid: (batch*heads, num_q_blocks, num_kv_blocks); the kv dimension is the
minormost (sequential on TPU), so VMEM scratch persists across kv steps.
MXU alignment: block_q/block_k multiples of 128 in production; d padded to a
lane multiple by the wrapper (ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30          # avoids -inf - -inf = nan in the rescale path
_LANES = 128             # TPU lane width; m/l scratch broadcast over lanes


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, sm_scale: float, block_q: int, block_k: int,
                  num_kv_blocks: int, q_offset: int):
    """One (q-block, kv-block) step of the online-softmax recurrence."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal block skip: a kv block strictly above the diagonal of this q
    # block contributes nothing; skip its FLOPs (and on real TPU, its DMA
    # cost is hidden by the same-shape pipeline).
    q_start = qi * block_q + q_offset                 # global q row of tile
    should_run = True
    if causal:
        should_run = ki * block_k <= q_start + block_q - 1

    @pl.when(should_run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (block_q, d)
        k = k_ref[0].astype(jnp.float32)              # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(                       # (block_q, block_k)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[:, :1]                          # (block_q, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_cur)                 # rescale factor
        p = jnp.exp(s - m_cur)                         # (block_q, block_k)
        l_ref[...] = l_ref[...] * corr + jnp.broadcast_to(
            jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, sm_scale: float | None = None,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Flat-head flash attention.  q: (BH, Sq, D); k,v: (BH, Sk, D).

    GQA head-group mapping is handled by the wrapper (ops.flash_attention),
    which expands k/v indices; here heads are 1:1.
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0, (Sq, block_q)
    assert Sk % block_k == 0, (Sk, block_k)
    num_q = Sq // block_q
    num_kv = Sk // block_k
    scale = sm_scale if sm_scale is not None else float(1.0 / np.sqrt(D))
    q_offset = Sk - Sq if causal else 0                # cached-decode offset

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=scale, block_q=block_q,
        block_k=block_k, num_kv_blocks=num_kv, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(BH, num_q, num_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),      # acc
            pltpu.VMEM((block_q, _LANES), jnp.float32),  # running max
            pltpu.VMEM((block_q, _LANES), jnp.float32),  # running denom
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
