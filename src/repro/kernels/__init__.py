"""Pallas TPU kernels for the compute hot-spots the paper's findings target.

flash_attention — streaming online-softmax prefill attention (vllm-20174)
rmsnorm        — single-HBM-pass fused norm (pytorch-76012 class)
fused_act      — fused SwiGLU / tanh-GELU (hf-39073: fused vs 5-kernel GELU)
ssm_scan       — VMEM-resident chunked selective scan (state never hits HBM)

Each kernel ships with a pure-jnp oracle in ref.py (also its energy-wasteful
twin for the differential debugger) and a jit'd wrapper in ops.py that
auto-selects interpret mode off-TPU.
"""

from repro.kernels.ops import (flash_attention, fused_gelu, fused_rmsnorm,
                               fused_ssm_scan, fused_swiglu)

__all__ = ["flash_attention", "fused_rmsnorm", "fused_swiglu", "fused_gelu",
           "fused_ssm_scan"]
