"""Version shims for the Pallas TPU API surface.

jax >= 0.7 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
the container may carry either generation.  Kernels import the name from
here so they compile against both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
