"""Fused RMSNorm Pallas kernel: one HBM pass, fp32 statistics in VMEM.

The unfused XLA form (square, mean, rsqrt, mul, mul — and a transpose when
the reduction axis is not minormost) is the wasteful twin in the zoo
(pytorch-76012 class).  The kernel reads each (block_rows x d) tile once,
computes the row statistic in registers/VMEM, and writes the tile once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)                 # (1, d)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm_2d(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
               block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (rows, d); w: (d,).  rows must divide by block_rows (wrapper pads)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w.reshape(1, d))
