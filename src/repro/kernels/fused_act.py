"""Fused activation kernels: SwiGLU gate and tanh-GELU in one HBM pass.

Direct adaptation of the paper's new-issue hf-39073 ("default GELU backend is
inefficient"): HuggingFace's unfused tanh-GELU launches 5 CUDA kernels — five
HBM round-trips over the activation tensor — where vLLM's fused kernel does
one, cutting the operator's energy by 77% (paper §6.3).  On TPU the same
structure applies: each unfused jnp op is one HBM read+write of the
(tokens x d_ff) tensor; the Pallas kernel holds the tile in VMEM and performs
all arithmetic before the single write-back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

_C = float(np.sqrt(2.0 / np.pi))


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.lax.logistic(g) * u).astype(o_ref.dtype)


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    inner = _C * (x + 0.044715 * x * x * x)
    o_ref[...] = (0.5 * x * (1.0 + jnp.tanh(inner))).astype(o_ref.dtype)


def _tiled_elementwise(kernel, args, out_dtype, *, block_rows: int,
                       interpret: bool) -> jax.Array:
    rows, d = args[0].shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    spec = pl.BlockSpec((block_rows, d), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[spec] * len(args),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, d), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)


def swiglu_2d(g: jax.Array, u: jax.Array, *, block_rows: int = 256,
              interpret: bool = False) -> jax.Array:
    """silu(g) * u.  g, u: (rows, d)."""
    assert g.shape == u.shape
    return _tiled_elementwise(_swiglu_kernel, (g, u), g.dtype,
                              block_rows=block_rows, interpret=interpret)


def gelu_2d(x: jax.Array, *, block_rows: int = 256,
            interpret: bool = False) -> jax.Array:
    """Fused tanh-GELU.  x: (rows, d)."""
    return _tiled_elementwise(_gelu_kernel, (x,), x.dtype,
                              block_rows=block_rows, interpret=interpret)
