"""Public jit'd wrappers for the Pallas kernels.

Every wrapper: (1) normalizes model-layout tensors into the kernel layout,
(2) picks hardware-aligned block sizes that divide the problem, (3) runs the
kernel in interpret mode automatically when no TPU is present (CPU test
containers), and (4) is shape-polymorphic enough for every assigned
architecture's head_dim / d_ff / state size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import fused_act, rmsnorm, ssm_scan


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pick_block(size: int, target: int) -> int:
    """Largest power-of-two divisor of ``size`` that is <= target."""
    b = 1
    while b * 2 <= min(size, target) and size % (b * 2) == 0:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    """Differentiable core on flat same-head-count tensors (B,H,S,D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    o = fa.flash_attention_bh(q.reshape(B * H, Sq, D),
                              k.reshape(B * H, Sk, D),
                              v.reshape(B * H, Sk, D),
                              causal=causal, sm_scale=sm_scale,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return o.reshape(B, H, Sq, D)


def _flash_fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    return _flash_core(q, k, v, causal, sm_scale, block_q, block_k,
                       interpret), (q, k, v)


def _flash_bwd(causal, sm_scale, block_q, block_k, interpret, res, g):
    # Recompute-based backward: re-derives the attention probabilities via
    # the reference path (fp32) and differentiates through it.  Keeps the
    # fused forward (the paper's energy win is in inference/prefill); a
    # dedicated dq/dk/dv flash backward kernel is a recorded §Perf lever.
    from repro.kernels import ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         sm_scale=sm_scale), q, k, v)
    return vjp(g)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B,H,Sq,D); k,v: (B,KV,Sk,D); returns (B,H,Sq,D).

    GQA: kv heads are index-expanded to q heads (no HBM materialization —
    XLA turns the gather of contiguous repeats into an access pattern);
    gradients scatter-add back onto the KV heads automatically.
    """
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    interp = _auto_interpret(interpret)
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    if H != KV:
        reps = H // KV
        head_map = jnp.arange(H, dtype=jnp.int32) // reps
        k = jnp.take(k, head_map, axis=1)
        v = jnp.take(v, head_map, axis=1)
    scale = sm_scale if sm_scale is not None else float(1.0 / np.sqrt(D))
    return _flash_core(q, k, v, causal, scale, bq, bk, interp)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                  block_rows: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """x: (..., d); w: (d,)."""
    shape = x.shape
    d = shape[-1]
    rows = int(np.prod(shape[:-1], dtype=np.int64))
    x2 = x.reshape(rows, d)
    br = _pick_block(rows, block_rows)
    out = rmsnorm.rmsnorm_2d(x2, w, eps=eps, block_rows=br,
                             interpret=_auto_interpret(interpret))
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# fused activations
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_swiglu(g: jax.Array, u: jax.Array, *, block_rows: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    shape = g.shape
    d = shape[-1]
    rows = int(np.prod(shape[:-1], dtype=np.int64))
    br = _pick_block(rows, block_rows)
    out = fused_act.swiglu_2d(g.reshape(rows, d), u.reshape(rows, d),
                              block_rows=br,
                              interpret=_auto_interpret(interpret))
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_gelu(x: jax.Array, *, block_rows: int = 256,
               interpret: bool | None = None) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    rows = int(np.prod(shape[:-1], dtype=np.int64))
    br = _pick_block(rows, block_rows)
    out = fused_act.gelu_2d(x.reshape(rows, d), block_rows=br,
                            interpret=_auto_interpret(interpret))
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def fused_ssm_scan(a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array,
                   *, chunk: int = 64, block_d: int = 128,
                   interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """a,b: (B,S,di,n); c: (B,S,n); h0: (B,di,n) — all fp32."""
    B, S, di, n = a.shape
    ck = _pick_block(S, chunk)
    bd = _pick_block(di, block_d)
    return ssm_scan.ssm_scan_fused(a, b, c, h0, chunk=ck, block_d=bd,
                                   interpret=_auto_interpret(interpret))
