"""Mutation-based detector validation: inject waste patterns into clean jaxprs.

The zoo (zoo/cases.py) validates detection on 20 hand-written twins; a
matcher or diagnosis regression that only shows up elsewhere would slip
through.  This module generates the twins instead: it traces a *clean*
program from ``models/`` / ``kernels/`` to its jaxpr, then replays that
jaxpr through a mutating interpreter that rewrites selected equations into a
semantically-equivalent-but-wasteful form — the paper's waste taxonomy as
executable mutations:

=====================  =====================================================
mutation class         injected pattern (expected diagnosis)
=====================  =====================================================
``dtype_upcast``       matmuls rebound with ``precision=HIGHEST`` — the
                       c1/c8 MXU-fast-path misconfiguration
                       (``param_difference``)
``redundant_recompute``  matmuls executed twice and averaged — c15-style
                       recomputation (``api_difference``)
``sync_in_loop``       an all-reduce inserted after every matmul — the c9
                       per-microbatch collective (``api_difference``)
``oversized_padding``  matmul operands zero-padded to 2x and the result
                       sliced back — dead rows through the MXU
                       (``api_difference``)
``op_split``           fused transcendentals (tanh/logistic/rsqrt/exp)
                       re-expressed as multi-op eager formulas — the n1
                       unfused-GELU pattern (``api_difference``)
``scan_body``          redundant recompute injected INSIDE ``lax.scan``
                       bodies — per-iteration waste hidden in a loop
                       super-node (``param_difference`` on the scan jaxpr)
``layout_thrash``      spurious transpose round-trips inserted on matmul
                       operands — layout churn through HBM
                       (``api_difference``)
``storage_upcast``     bf16 non-matmul ops rebound through f32 storage
                       (convert up, compute, convert back) — doubled
                       element bytes on the VPU path (``api_difference``)
=====================  =====================================================

Because the mutant is an ordinary Python callable replaying the clean jaxpr
with rewritten binds, ``Session.capture`` traces it like any other candidate
— the mutation materializes as real operators in the captured graph, and the
differential pipeline must (1) gate it as the same task, (2) localize the
injected region, and (3) diagnose the planted root cause.
:func:`validate_detector` runs the full scenario matrix and reports
detections and misclassifications per mutation class.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.diagnose import DIAGNOSIS_KINDS, DIAGNOSIS_SUBKINDS
# The replay interpreter and bind helpers moved to the shared bidirectional
# rewrite engine (repro.optimize.engine) when the inverse rewrites landed;
# the historical names stay importable from here.
from repro.optimize.engine import _INLINE_PRIMITIVES  # noqa: F401
from repro.optimize.engine import RewriteRule
from repro.optimize.engine import bind_eqn as _bind
from repro.optimize.engine import bind_eqn_with_params as _bind_with_params
from repro.optimize.engine import nested_jaxpr as _nested_jaxpr  # noqa: F401
from repro.optimize.engine import replay_jaxpr


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.result_type(x), jnp.floating)


class InapplicableMutationError(ValueError):
    """A mutation found no applicable site in the program's jaxpr.

    Raised by :func:`make_mutant` instead of silently returning an
    unchanged twin (a mutant identical to its clean program makes any
    downstream "the detector must alarm" check vacuously green — the PR 7
    serving demo hit exactly that with ``dtype_upcast`` on a bf16 model).
    ``reasons`` carries the per-site near-miss notes the mutation recorded.
    """

    def __init__(self, mutation: "Mutation", fn_name: str):
        self.mutation_name = mutation.name
        self.reasons = list(mutation.skipped)
        detail = ("; ".join(self.reasons) if self.reasons
                  else "no applicable equation in the jaxpr")
        super().__init__(
            f"mutation {mutation.name!r} found no applicable site in "
            f"{fn_name!r}: {detail}")


# ---------------------------------------------------------------------------
# mutations
# ---------------------------------------------------------------------------

class Mutation(RewriteRule):
    """One waste pattern, applied at replay time.

    Subclasses override :meth:`rewrite` to return replacement output values
    for an equation (or ``None`` to leave it untouched).  ``max_sites``
    bounds how many applicable sites are mutated (default: all);
    ``applied`` counts the sites actually rewritten in the last trace;
    near-miss sites record why via :meth:`RewriteRule.decline` so a
    zero-site mutation can explain itself.
    """

    name: str = "?"
    expected_kinds: tuple[str, ...] = ()

    def rewrite(self, eqn, invals) -> list[Any] | None:
        raise NotImplementedError

    def on_eqn(self, eqn, invals, ctx=None) -> list[Any] | None:
        out = self.rewrite(eqn, invals)
        if out is not None and not isinstance(out, (list, tuple)):
            out = [out]
        return list(out) if out is not None else None


class DtypeUpcast(Mutation):
    """Rebind matmuls with ``precision=HIGHEST`` (3-pass fp32 emulation on
    the MXU) — the c1/c8 misconfiguration.  Same operator multiset, one
    diverging equation param, so the correct diagnosis is a
    ``param_difference`` on ``dot_general.precision``."""

    name = "dtype_upcast"
    expected_kinds = ("param_difference", "config_difference")

    def rewrite(self, eqn, invals):
        if eqn.primitive.name != "dot_general":
            return None
        if "HIGHEST" in str(eqn.params.get("precision")).upper():
            self.decline("dot_general already bound at precision=HIGHEST")
            return None
        # f32 dots only: HIGHEST on bf16 storage changes the accumulation
        # numerics, so the mutant would no longer be bitwise-equivalent and
        # the matcher could not localize the region
        if any(getattr(x, "dtype", None) == jnp.bfloat16 for x in invals):
            self.decline("dot_general runs on bf16 storage (HIGHEST would "
                         "change accumulation numerics); upcast an f32 dot "
                         "or use a program with a master-precision dot")
            return None
        if not self._take():
            return None
        params = dict(eqn.params)
        params["precision"] = (jax.lax.Precision.HIGHEST,
                               jax.lax.Precision.HIGHEST)
        return _bind_with_params(eqn, invals, params)


class RedundantRecompute(Mutation):
    """Execute every matmul twice and average the (identical) results — the
    c15 recompute-instead-of-share pattern.  ``0.5*a + 0.5*a`` is bitwise
    ``a`` for finite floats, so outputs still match exactly."""

    name = "redundant_recompute"
    expected_kinds = ("api_difference",)

    def rewrite(self, eqn, invals):
        if eqn.primitive.name != "dot_general" or not _is_float(invals[0]):
            return None                      # 0.5-averaging an int dot would
        if not self._take():                 # promote its dtype
            return None
        (o1,) = _bind(eqn, invals)
        (o2,) = _bind(eqn, invals)
        return [o1 * 0.5 + o2 * 0.5]


class SyncInLoop(Mutation):
    """Insert an all-reduce after every matmul — the c9 per-microbatch
    collective.  On the single-device mesh the psum is semantically the
    identity, but the jaxpr carries a genuine collective that costs.py
    prices as interconnect traffic."""

    name = "sync_in_loop"
    expected_kinds = ("api_difference",)

    @staticmethod
    def _all_reduce(x):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
        return shard_map(lambda y: jax.lax.psum(y, "dp"), mesh=mesh,
                         in_specs=P(), out_specs=P())(x)

    def rewrite(self, eqn, invals):
        if eqn.primitive.name != "dot_general" or not self._take():
            return None
        (out,) = _bind(eqn, invals)
        return [self._all_reduce(out)] if _is_float(out) else [out]


class OversizedPadding(Mutation):
    """Zero-pad the lhs of every matmul to twice its leading free dimension
    and slice the dead rows back off the result — over-allocated sequence /
    batch padding pushed through the MXU."""

    name = "oversized_padding"
    expected_kinds = ("api_difference",)

    def rewrite(self, eqn, invals):
        if eqn.primitive.name != "dot_general":
            return None
        lhs, rhs = invals
        (lc, _rc), (lb, _rb) = eqn.params["dimension_numbers"]
        free = [d for d in range(lhs.ndim) if d not in set(lc) | set(lb)]
        if not free or not self._take():
            return None
        d0, n = free[0], lhs.shape[free[0]]
        cfg = [(0, n, 0) if d == d0 else (0, 0, 0) for d in range(lhs.ndim)]
        padded = jax.lax.pad(lhs, jnp.zeros((), lhs.dtype), cfg)
        (out,) = _bind(eqn, [padded, rhs])
        out_axis = len(lb)                   # out dims: batch, lhs free, rhs free
        return [jax.lax.slice_in_dim(out, 0, n, axis=out_axis)]


class OpSplit(Mutation):
    """Re-express fused transcendentals as eager multi-op formulas (one HBM
    round-trip per op) — the n1 unfused-GELU backend pattern."""

    name = "op_split"
    expected_kinds = ("api_difference",)

    def rewrite(self, eqn, invals):
        # rsqrt is deliberately not split: it only ever runs on the (rows, 1)
        # reduced statistics, a region too small for a 10% energy delta
        prim = eqn.primitive.name
        if prim not in ("tanh", "logistic", "exp"):
            return None
        (x,) = invals
        # f32 only: the split formulas round through exp, and in bf16 the
        # accumulated rounding (~0.8%/step) can breach the equivalence gate
        if not _is_float(x) or jnp.result_type(x) != jnp.float32:
            self.decline(f"{prim} runs on {jnp.result_type(x)} (split "
                         "formulas only stay within the gate in f32)")
            return None
        if not self._take():
            return None
        if prim == "tanh":
            xc = jnp.clip(x, -20.0, 20.0)    # exp(2x) stays finite
            t = jnp.exp(2.0 * xc)
            return [(t - 1.0) / (t + 1.0)]
        if prim == "logistic":
            return [1.0 / (1.0 + jnp.exp(-x))]
        h = jnp.exp(x * 0.5)                 # exp: split into two half-exps
        return [h * h]


def _contains_dot(closed) -> bool:
    """Whether a (closed) jaxpr binds a dot_general anywhere, recursively."""
    from jax._src.core import ClosedJaxpr, Jaxpr
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            return True
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for sub in vs:
                if isinstance(sub, (ClosedJaxpr, Jaxpr)) \
                        and _contains_dot(sub):
                    return True
    return False


class ScanBodyWaste(Mutation):
    """Inject redundant recompute INSIDE ``lax.scan`` bodies: the scan is
    re-bound with a body that replays the original body jaxpr under a
    :class:`RedundantRecompute` hook, so every body matmul runs twice per
    iteration.  The outer graphs keep identical operator multisets — both
    sides carry one ``scan`` super-node — so the correct diagnosis is a
    ``param_difference`` (the scan's body jaxpr is the diverging param),
    exercising costs.py's trip-count-scaled loop pricing."""

    name = "scan_body"
    expected_kinds = ("param_difference",)

    def rewrite(self, eqn, invals):
        if eqn.primitive.name != "scan":
            return None
        body = eqn.params["jaxpr"]
        if not _contains_dot(body):
            self.decline("scan body binds no dot_general to recompute")
            return None
        if not self._take():
            return None
        num_consts = eqn.params["num_consts"]
        num_carry = eqn.params["num_carry"]
        consts = list(invals[:num_consts])
        init = list(invals[num_consts:num_consts + num_carry])
        xs = tuple(invals[num_consts + num_carry:])
        inner = RedundantRecompute()

        def body_fn(carry, x):
            x_leaves = [] if x is None else list(x)
            outs = _replay(body, [*consts, *list(carry), *x_leaves], inner)
            return tuple(outs[:num_carry]), tuple(outs[num_carry:])

        carry_out, ys = jax.lax.scan(
            body_fn, tuple(init), xs if xs else None,
            length=eqn.params.get("length"),
            reverse=eqn.params.get("reverse", False),
            unroll=eqn.params.get("unroll", 1))
        return [*carry_out, *ys]


class LayoutThrash(Mutation):
    """Insert transpose round-trips on every matmul's operands — spurious
    layout churn through HBM around the MXU.  The values are bitwise
    unchanged (the full-reverse permutation is an involution) but each
    matmul gains four data-movement operators, so the correct diagnosis is
    an ``api_difference`` with extra ``transpose`` ops on the wasteful
    side."""

    name = "layout_thrash"
    expected_kinds = ("api_difference",)

    @staticmethod
    def _round_trip(x):
        if getattr(x, "ndim", 0) < 2:
            return x
        perm = tuple(reversed(range(x.ndim)))
        return jax.lax.transpose(jax.lax.transpose(x, perm), perm)

    def rewrite(self, eqn, invals):
        if eqn.primitive.name != "dot_general":
            return None
        if getattr(invals[0], "ndim", 0) < 2 or not self._take():
            return None
        return _bind(eqn, [self._round_trip(x) for x in invals])


class StorageUpcast(Mutation):
    """Rebind bf16 non-matmul ops through f32 storage: convert the operands
    up, compute, convert the result back.  Every mutated element pays double
    the HBM bytes plus two conversion passes — the storage-dtype analogue of
    the c1/c8 compute misconfiguration, on ops where no MXU is involved."""

    name = "storage_upcast"
    expected_kinds = ("api_difference",)

    _TARGETS = ("tanh", "logistic", "exp", "add", "mul")

    def rewrite(self, eqn, invals):
        if eqn.primitive.name not in self._TARGETS:
            return None
        if not all(hasattr(x, "dtype") and x.dtype == jnp.bfloat16
                   for x in invals):
            self.decline(f"{eqn.primitive.name} operands are not uniformly "
                         "bf16 (nothing to bounce through f32 storage)")
            return None
        if not self._take():
            return None
        out = _bind(eqn, [x.astype(jnp.float32) for x in invals])
        return [o.astype(jnp.bfloat16) for o in out]


MUTATIONS: dict[str, type[Mutation]] = {
    m.name: m for m in (DtypeUpcast, RedundantRecompute, SyncInLoop,
                        OversizedPadding, OpSplit, ScanBodyWaste,
                        LayoutThrash, StorageUpcast)
}

assert all(k in DIAGNOSIS_KINDS for m in MUTATIONS.values()
           for k in m.expected_kinds)
# the finer subkind taxonomy (and the inverse-rewrite registry keyed on it)
# must stay in lockstep with the mutation classes
assert set(MUTATIONS) == set(DIAGNOSIS_SUBKINDS), \
    (set(MUTATIONS), set(DIAGNOSIS_SUBKINDS))


def default_mutations() -> list[Mutation]:
    return [cls() for cls in MUTATIONS.values()]


# ---------------------------------------------------------------------------
# jaxpr replay with mutation hooks
# ---------------------------------------------------------------------------

def _replay(closed, flat_args: Sequence[Any], mutation: Mutation) -> list[Any]:
    return replay_jaxpr(closed, flat_args, mutation)


def make_mutant(fn: Callable, mutation: Mutation, example_args: Sequence[Any],
                *, name: str | None = None,
                allow_zero_sites: bool = False) -> tuple[Callable, int]:
    """Build the mutated twin of ``fn`` and count its mutated sites.

    Returns ``(mutant, sites)``.  A mutation that finds no applicable
    equation raises :class:`InapplicableMutationError` carrying the
    mutation's recorded skip reasons — a zero-site mutant is bitwise the
    clean program, which silently turns "the detector must alarm on this"
    checks vacuous (the PR 7 serving demo shipped exactly that).  Pass
    ``allow_zero_sites=True`` to get the old ``(mutant, 0)`` behavior for
    callers that probe applicability themselves.  The mutant is an ordinary
    callable over the same argument pytree, so it can be captured, jitted,
    or compared like any hand-written candidate.
    """
    example_args = tuple(example_args)
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*example_args)
    out_tree = jax.tree_util.tree_structure(out_shape)

    def mutant(*args):
        mutation.reset()
        outs = _replay(closed, jax.tree_util.tree_leaves(args), mutation)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    mutant.__name__ = name or (f"{getattr(fn, '__name__', 'fn')}"
                               f"__{mutation.name}")
    mutation.reset()
    jax.eval_shape(mutant, *example_args)
    if mutation.applied == 0 and not allow_zero_sites:
        raise InapplicableMutationError(
            mutation, getattr(fn, "__name__", "fn"))
    return mutant, mutation.applied


# ---------------------------------------------------------------------------
# clean programs (models/ + kernels/)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CleanProgram:
    """A small, fast, waste-free program drawn from the real model zoo."""

    name: str
    fn: Callable
    make_args: Callable[[], tuple]


def clean_programs() -> list[CleanProgram]:
    """Clean programs spanning matmul, attention, norm, activation, loop,
    and bf16 ops.

    Sizes are small (fast through the instrumenting interpreter) but the
    matmul contraction dims stay >= 64 so the dots have enough arithmetic
    intensity for a flop-side mutation (dtype_upcast's 3x fp32 emulation)
    to clear the 10% region-energy detection threshold over the
    memory-access energy floor.  The ``scan_*`` programs keep their dots
    INSIDE ``lax.scan`` bodies (scan_body mutation targets); the ``*_bf16``
    programs run in bfloat16 storage (storage_upcast targets).
    """
    from repro.kernels import ref
    from repro.models import layers

    k = jax.random.key(20260801)
    ks = list(jax.random.split(k, 12))

    mlp_params = layers.init_params(layers.mlp_schema(128, 256, "float32"),
                                    ks[0])

    def mlp_block(x):
        return layers.mlp_apply(mlp_params, x)

    scale = jax.random.normal(ks[1], (128,), jnp.float32) * 0.1 + 1.0
    w_norm = jax.random.normal(ks[2], (128, 128), jnp.float32) * 0.1
    w_gelu = jax.random.normal(ks[3], (128, 128), jnp.float32) * 0.1

    def rmsnorm_linear(x):
        return layers.rms_norm(x, scale) @ w_norm

    def gelu_dense(x):
        return ref.gelu_tanh(x @ w_gelu)

    def attention_block(q, k_, v):
        return ref.attention(q, k_, v, causal=False)

    w_scan = jax.random.normal(ks[8], (128, 128), jnp.float32) * 0.05
    w_scan2 = jax.random.normal(ks[9], (128, 128), jnp.float32) * 0.05

    def scan_mlp(x):
        def step(c, _):
            return jnp.tanh(c @ w_scan), None
        out, _ = jax.lax.scan(step, x, None, length=4)
        return out

    def scan_residual(x):
        def step(c, _):
            return c + 0.5 * jnp.tanh(c @ w_scan2), None
        out, _ = jax.lax.scan(step, x, None, length=4)
        return out

    w_b16 = (jax.random.normal(ks[10], (128, 128), jnp.float32) * 0.1
             ).astype(jnp.bfloat16)

    def gelu_dense_bf16(x):
        # bf16-native tanh-GELU (ref.gelu_tanh upcasts to f32 internally,
        # which would leave no bf16 elementwise sites to mutate)
        y = x @ w_b16
        inner = 0.7978845608 * (y + 0.044715 * (y * y * y))
        return 0.5 * y * (1.0 + jnp.tanh(inner))

    def act_chain_bf16(x):
        return jnp.tanh(x) * jax.nn.sigmoid(x + jnp.bfloat16(1.0))

    w_master = jax.random.normal(ks[4], (128, 128), jnp.float32) * 0.1

    def mlp_bf16_master(x):
        # mixed precision with f32 master weights: bf16 storage upcast to
        # f32 around the dot.  This is the one program where dtype_upcast
        # has a site on a bf16 model (the dot itself runs f32), closing the
        # gap PR 7 hit: serving models default to bf16, where dtype_upcast
        # declines every dot and used to yield a silent zero-site mutant.
        h = x.astype(jnp.float32) @ w_master
        return jnp.tanh(h).astype(jnp.bfloat16)

    def _qkv():
        kq, kk, kv = jax.random.split(ks[4], 3)
        shape = (1, 2, 64, 128)   # head_dim 128: the score matmul's 3x fp32
        # flop term must outweigh its memory-access + idle energy floor
        return (jax.random.normal(kq, shape, jnp.float32),
                jax.random.normal(kk, shape, jnp.float32),
                jax.random.normal(kv, shape, jnp.float32))

    return [
        CleanProgram("mlp_swiglu", mlp_block,
                     lambda: (jax.random.normal(ks[5], (2, 32, 128),
                                                jnp.float32),)),
        CleanProgram("attention_ref", attention_block, _qkv),
        CleanProgram("rmsnorm_linear", rmsnorm_linear,
                     lambda: (jax.random.normal(ks[6], (64, 128),
                                                jnp.float32),)),
        CleanProgram("gelu_dense", gelu_dense,
                     lambda: (jax.random.normal(ks[7], (64, 128),
                                                jnp.float32),)),
        CleanProgram("scan_mlp", scan_mlp,
                     lambda: (jax.random.normal(ks[8], (64, 128),
                                                jnp.float32),)),
        CleanProgram("scan_residual", scan_residual,
                     lambda: (jax.random.normal(ks[9], (64, 128),
                                                jnp.float32),)),
        CleanProgram("gelu_dense_bf16", gelu_dense_bf16,
                     lambda: (jax.random.normal(ks[10], (64, 128),
                                                jnp.float32
                                                ).astype(jnp.bfloat16),)),
        CleanProgram("act_chain_bf16", act_chain_bf16,
                     lambda: (jax.random.normal(ks[11], (128, 128),
                                                jnp.float32
                                                ).astype(jnp.bfloat16),)),
        CleanProgram("mlp_bf16_master", mlp_bf16_master,
                     lambda: (jax.random.normal(ks[6], (64, 128),
                                                jnp.float32
                                                ).astype(jnp.bfloat16),)),
    ]


# ---------------------------------------------------------------------------
# scenario generation + detector validation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Scenario:
    program: CleanProgram
    mutation: Mutation
    mutant: Callable
    sites: int

    @property
    def id(self) -> str:
        return f"{self.mutation.name}:{self.program.name}"


def generate_scenarios(programs: Sequence[CleanProgram] | None = None,
                       mutation_names: Sequence[str] | None = None
                       ) -> list[Scenario]:
    """The cross product of clean programs x mutations, minus inapplicable
    pairs (mutations that found no site in a program's jaxpr)."""
    programs = list(programs) if programs is not None else clean_programs()
    names = list(mutation_names) if mutation_names is not None \
        else list(MUTATIONS)
    out: list[Scenario] = []
    for prog in programs:
        args = prog.make_args()
        for mname in names:
            mutation = MUTATIONS[mname]()
            mutant, sites = make_mutant(prog.fn, mutation, args,
                                        allow_zero_sites=True)
            if sites == 0:
                continue                     # inapplicable pair, by design
            out.append(Scenario(program=prog, mutation=mutation,
                                mutant=mutant, sites=sites))
    return out


@dataclasses.dataclass
class ScenarioResult:
    scenario_id: str
    program: str
    mutation: str
    sites: int
    detected: bool
    kinds: list[str]             # diagnosis kinds of the waste findings
    kind_ok: bool                # some kind matches the mutation's expectation
    expected_kinds: tuple[str, ...]
    energy_clean_j: float
    energy_mutant_j: float
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.detected and self.kind_ok and self.error is None


@dataclasses.dataclass
class ValidationResult:
    """Detector validation over the generated scenario space."""

    results: list[ScenarioResult]

    def by_class(self) -> dict[str, list[ScenarioResult]]:
        out: dict[str, list[ScenarioResult]] = {}
        for r in self.results:
            out.setdefault(r.mutation, []).append(r)
        return out

    def misclassified(self) -> dict[str, list[ScenarioResult]]:
        """Per mutation class: scenarios detected but with a wrong root
        cause, or not detected at all."""
        return {cls: bad for cls, rs in self.by_class().items()
                if (bad := [r for r in rs if not r.ok])}

    def validated_classes(self, min_programs: int = 2) -> set[str]:
        """Mutation classes detected AND correctly classified on at least
        ``min_programs`` distinct clean programs."""
        return {cls for cls, rs in self.by_class().items()
                if len({r.program for r in rs if r.ok}) >= min_programs}

    def summary(self) -> str:
        lines = ["=== mutation-based detector validation ==="]
        for cls, rs in sorted(self.by_class().items()):
            ok = [r for r in rs if r.ok]
            lines.append(
                f"{cls:22} detected+classified on "
                f"{len({r.program for r in ok})}/{len({r.program for r in rs})}"
                f" programs ({len(ok)}/{len(rs)} scenarios)")
            for r in rs:
                if not r.ok:
                    why = (r.error or
                           ("not detected" if not r.detected else
                            f"misclassified: got {r.kinds or ['<none>']}, "
                            f"expected one of {list(r.expected_kinds)}"))
                    lines.append(f"    MISS {r.scenario_id}: {why}")
        return "\n".join(lines)


def validate_detector(scenarios: Sequence[Scenario] | None = None,
                      session=None, *, output_rtol: float = 1e-2
                      ) -> ValidationResult:
    """Capture each mutant against its clean twin and score the debugger.

    For every scenario the mutant is candidate A and the clean program
    candidate B; success means (1) at least one confirmed energy-waste
    region with the mutant on the wasteful side and (2) a diagnosis whose
    kind matches the mutation class's expectation.  Clean programs are
    captured once and reused across their scenarios.
    """
    from repro.core.session import Session

    session = session or Session()
    scenarios = list(scenarios) if scenarios is not None \
        else generate_scenarios()
    clean_arts: dict[str, Any] = {}
    clean_args: dict[str, tuple] = {}
    results: list[ScenarioResult] = []
    for sc in scenarios:
        pname = sc.program.name
        if pname not in clean_arts:
            clean_args[pname] = sc.program.make_args()
            clean_arts[pname] = session.capture(
                sc.program.fn, clean_args[pname], name=pname)
        clean = clean_arts[pname]
        try:
            mut_art = session.capture(sc.mutant, clean_args[pname],
                                      name=sc.mutant.__name__)
            rep = session.compare(mut_art, clean, output_rtol=output_rtol)
            waste = [f for f in rep.waste_findings if f.wasteful_side == "A"]
            kinds = [f.diagnosis.kind for f in waste if f.diagnosis]
            results.append(ScenarioResult(
                scenario_id=sc.id, program=pname, mutation=sc.mutation.name,
                sites=sc.sites, detected=bool(waste), kinds=kinds,
                kind_ok=any(k in sc.mutation.expected_kinds for k in kinds),
                expected_kinds=sc.mutation.expected_kinds,
                energy_clean_j=clean.total_energy_j,
                energy_mutant_j=mut_art.total_energy_j))
        except Exception as e:               # scenario-level isolation
            results.append(ScenarioResult(
                scenario_id=sc.id, program=pname, mutation=sc.mutation.name,
                sites=sc.sites, detected=False, kinds=[], kind_ok=False,
                expected_kinds=sc.mutation.expected_kinds,
                energy_clean_j=float("nan"), energy_mutant_j=float("nan"),
                error=f"{type(e).__name__}: {e}"))
    return ValidationResult(results)
