"""Loopback conditional-put HTTP store server — an S3/GCS stand-in.

Serves a :class:`~repro.core.store._FsLayout` directory over http with the
small conditional dialect the writable :class:`~repro.core.store.RemoteStore`
speaks, so tests and CI can exercise fleet writes without a real object
store:

* ``GET``/``HEAD`` — body + a strong ``ETag`` (sha256 of the bytes, the
  GCS-generation/S3-ETag stand-in).
* ``PUT`` — honours ``If-None-Match: *`` (create-only; 412 if the object
  exists) and ``If-Match: <etag>`` (replace-only-if-unchanged; 412 on
  mismatch or absence).  The precondition check and the write happen under
  one lock, which is exactly the atomicity S3/GCS conditional writes
  provide.  Unconditional PUTs replace.
* ``DELETE`` — idempotent remove.

Chaos hooks: set ``server.fail_puts = n`` to have the next ``n`` PUTs
answer 503 (a transient that :class:`~repro.core.store.RetryPolicy`
absorbs), and ``server.fail_gets = n`` likewise for reads.

Use as a context manager::

    with serve_store(tmp_path / "fleet") as srv:
        store = RemoteStore(srv.url, writable=True)
"""

from __future__ import annotations

import contextlib
import hashlib
import os
import tempfile
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path


def _etag(data: bytes) -> str:
    return '"' + hashlib.sha256(data).hexdigest() + '"'


class _Handler(BaseHTTPRequestHandler):
    server_version = "MagnetonStore/1"

    def log_message(self, *args) -> None:      # keep test output quiet
        pass

    def _target(self) -> Path | None:
        rel = self.path.lstrip("/")
        root = self.server.root
        if not rel:
            return None
        path = (root / rel).resolve()
        if root.resolve() not in path.parents and path != root.resolve():
            return None                        # traversal attempt
        return path

    def _deny(self, code: int) -> None:
        self.send_response(code)
        self.send_header("Content-Length", "0")
        self.end_headers()

    def do_GET(self, head: bool = False) -> None:
        if self.server.fail_gets > 0:
            self.server.fail_gets -= 1
            self._deny(503)
            return
        path = self._target()
        with self.server.lock:
            if path is None or not path.is_file():
                self._deny(404)
                return
            data = path.read_bytes()
        self.send_response(200)
        self.send_header("ETag", _etag(data))
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        if not head:
            self.wfile.write(data)

    def do_HEAD(self) -> None:
        self.do_GET(head=True)

    def do_PUT(self) -> None:
        path = self._target()
        length = int(self.headers.get("Content-Length") or 0)
        data = self.rfile.read(length)
        if path is None:
            self._deny(400)
            return
        if self.server.fail_puts > 0:
            self.server.fail_puts -= 1
            self._deny(503)
            return
        if self.server.reject_writes:
            self._deny(405)
            return
        with self.server.lock:                 # precondition+write atomic
            exists = path.is_file()
            if self.headers.get("If-None-Match") == "*" and exists:
                self._deny(412)
                return
            if_match = self.headers.get("If-Match")
            if if_match is not None and (
                    not exists or _etag(path.read_bytes()) != if_match):
                self._deny(412)
                return
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
            self.server.puts += 1
        self.send_response(200 if exists else 201)
        self.send_header("ETag", _etag(data))
        self.send_header("Content-Length", "0")
        self.end_headers()

    def do_DELETE(self) -> None:
        path = self._target()
        if self.server.reject_writes:
            self._deny(405)
            return
        with self.server.lock:
            if path is not None and path.is_file():
                path.unlink()
        self._deny(204)


class StoreHTTPServer(ThreadingHTTPServer):
    """Threaded loopback server over one store root directory."""

    daemon_threads = True

    def __init__(self, root: str | Path, host: str = "127.0.0.1",
                 port: int = 0):
        super().__init__((host, port), _Handler)
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.lock = threading.Lock()
        self.fail_puts = 0                     # chaos: next n PUTs -> 503
        self.fail_gets = 0                     # chaos: next n GETs -> 503
        self.reject_writes = False             # readonly mirror: PUT -> 405
        self.puts = 0

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


@contextlib.contextmanager
def serve_store(root: str | Path):
    """Run a :class:`StoreHTTPServer` over ``root`` for a ``with`` block."""
    srv = StoreHTTPServer(root)
    thread = threading.Thread(target=srv.serve_forever,
                              name="magneton-httpstore", daemon=True)
    thread.start()
    try:
        yield srv
    finally:
        srv.shutdown()
        srv.server_close()
        thread.join(timeout=5)
