"""Golden-baseline store: recorded zoo expectations + replayable artifacts.

A *baseline* is the committed, human-reviewable expectation for one zoo
case: did the debugger detect waste, on which side, with which root-cause
class, at which (analytic, deterministic) energies — plus a declared
tolerance for the energy fields.  Baselines live as one JSON file per case
under ``tests/baselines/``; the golden *artifacts* backing them live in a
content-addressed :class:`~repro.core.artifact.ArtifactStore` under
``tests/baselines/store`` (not committed — regenerable by ``record``).

Two replay modes:

* ``check`` (live) — re-captures the case through the session; with a warm
  store this is a pure cache hit, with a cold one it re-runs the pipeline.
  Either way the fresh comparison is diffed against the committed JSON.
* ``check --offline`` — loads the golden artifacts from the store and
  re-runs matching + classification + diagnosis with **zero instrumented
  execution** (the record-time compare memoized every phase-2 tensor value
  it fetched onto the artifacts; a replay that needs values beyond that
  set has, by definition, changed matcher behavior and is reported as
  drift).  This is the CI drift gate: a matcher or diagnosis regression
  changes the replayed findings even though no candidate code ran.

Drift is reported field-by-field as :class:`Drift` records, never as a bare
boolean, so a CI failure names exactly what moved.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Mapping, Sequence

from repro.core.artifact import ArtifactStore, ArtifactValueError
from repro.core.diagnose import DIAGNOSIS_KINDS
from repro.core.report import Report
from repro.core.session import Session
from repro.core.store import StoreError
from repro.zoo.cases import Case

BASELINE_FORMAT_VERSION = 1
DEFAULT_BASELINE_DIR = "tests/baselines"
# Offline replay is deterministic (same artifacts, same matcher, same
# pricing), so the default declared tolerance is tight; recorders can widen
# it per-case for energies that depend on measured time (replay backend).
DEFAULT_ENERGY_RTOL = 1e-6


class BaselineError(RuntimeError):
    """A baseline could not be recorded or replayed."""


class MissingBaselineError(BaselineError, KeyError):
    """No recorded baseline for the requested case."""


@dataclasses.dataclass
class WasteExpectation:
    """The committed signature of one energy-waste finding."""

    wasteful_side: str           # 'A' (inefficient twin) | 'B'
    kind: str | None             # diagnosis root-cause class
    energy_a_j: float
    energy_b_j: float
    nodes_a: int                 # region sizes, not node ids: stable under
    nodes_b: int                 # graph-identical re-traces

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "WasteExpectation":
        return cls(wasteful_side=d["wasteful_side"], kind=d["kind"],
                   energy_a_j=d["energy_a_j"], energy_b_j=d["energy_b_j"],
                   nodes_a=d["nodes_a"], nodes_b=d["nodes_b"])


@dataclasses.dataclass
class Baseline:
    """Committed expectation for one zoo case."""

    case_id: str
    paper_id: str
    category: str
    expect_detect: bool
    backend_id: str
    sample_seeds: list[int]
    detected: bool
    total_energy_a_j: float
    total_energy_b_j: float
    regions: int
    eq_tensor_pairs: int         # matcher-quality canary
    waste: list[WasteExpectation]
    tradeoffs: int
    comparable: int
    energy_rtol: float = DEFAULT_ENERGY_RTOL

    @classmethod
    def from_report(cls, case: Case, report: Report, *, backend_id: str,
                    sample_seeds: Sequence[int],
                    energy_rtol: float = DEFAULT_ENERGY_RTOL) -> "Baseline":
        waste = [WasteExpectation(
            wasteful_side=f.wasteful_side,
            kind=f.diagnosis.kind if f.diagnosis else None,
            energy_a_j=f.energy_a_j, energy_b_j=f.energy_b_j,
            nodes_a=len(f.nodes_a), nodes_b=len(f.nodes_b))
            for f in report.waste_findings]
        for w in waste:
            if w.kind is not None and w.kind not in DIAGNOSIS_KINDS:
                raise BaselineError(f"{case.id}: unknown diagnosis kind "
                                    f"{w.kind!r} (not in {DIAGNOSIS_KINDS})")
        by_cls = {"tradeoff": 0, "comparable": 0}
        for f in report.findings:
            if f.classification in by_cls:
                by_cls[f.classification] += 1
        return cls(case_id=case.id, paper_id=case.paper_id,
                   category=case.category, expect_detect=case.expect_detect,
                   backend_id=backend_id,
                   sample_seeds=[int(s) for s in sample_seeds],
                   detected=bool(waste),
                   total_energy_a_j=report.total_energy_a_j,
                   total_energy_b_j=report.total_energy_b_j,
                   regions=len(report.findings),
                   eq_tensor_pairs=int(report.meta.get("eq_tensor_pairs", 0)),
                   waste=waste, tradeoffs=by_cls["tradeoff"],
                   comparable=by_cls["comparable"], energy_rtol=energy_rtol)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["format_version"] = BASELINE_FORMAT_VERSION
        return json.dumps(d, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, data: str | Mapping[str, Any]) -> "Baseline":
        d = json.loads(data) if isinstance(data, str) else dict(data)
        version = d.pop("format_version", BASELINE_FORMAT_VERSION)
        if version != BASELINE_FORMAT_VERSION:
            raise BaselineError(f"baseline format v{version}; this build "
                                f"reads v{BASELINE_FORMAT_VERSION}")
        d["waste"] = [WasteExpectation.from_dict(w) for w in d["waste"]]
        return cls(**d)


@dataclasses.dataclass
class Drift:
    """One divergence between a committed baseline and a fresh replay."""

    case_id: str
    field: str
    expected: Any
    actual: Any

    def __str__(self) -> str:
        return (f"{self.case_id}: {self.field} drifted — "
                f"expected {self.expected!r}, got {self.actual!r}")


def rel_diff(a: float, b: float) -> float:
    """Symmetric relative difference, safe at zero — the drift metric the
    baseline gate and the serving-audit drift check (repro.audit) share."""
    scale = max(abs(a), abs(b), 1e-30)
    return abs(a - b) / scale


_rel_diff = rel_diff                           # historical private alias


def diff_baselines(expected: Baseline, actual: Baseline) -> list[Drift]:
    """Field-by-field drift between a committed baseline and a fresh one.

    Structural fields (detection verdict, waste sign, root-cause class,
    finding/region counts, matched-pair count) compare exactly; energy
    fields compare within the baseline's declared ``energy_rtol``.
    """
    cid = expected.case_id
    out: list[Drift] = []

    def exact(field: str, e, a) -> None:
        if e != a:
            out.append(Drift(cid, field, e, a))

    def energy(field: str, e: float, a: float) -> None:
        if _rel_diff(e, a) > expected.energy_rtol:
            out.append(Drift(cid, field, e, a))

    exact("backend_id", expected.backend_id, actual.backend_id)
    exact("sample_seeds", expected.sample_seeds, actual.sample_seeds)
    exact("detected", expected.detected, actual.detected)
    exact("regions", expected.regions, actual.regions)
    exact("eq_tensor_pairs", expected.eq_tensor_pairs, actual.eq_tensor_pairs)
    exact("waste_findings", len(expected.waste), len(actual.waste))
    exact("tradeoffs", expected.tradeoffs, actual.tradeoffs)
    exact("comparable", expected.comparable, actual.comparable)
    energy("total_energy_a_j", expected.total_energy_a_j,
           actual.total_energy_a_j)
    energy("total_energy_b_j", expected.total_energy_b_j,
           actual.total_energy_b_j)
    for i, (we, wa) in enumerate(zip(expected.waste, actual.waste)):
        exact(f"waste[{i}].wasteful_side", we.wasteful_side, wa.wasteful_side)
        exact(f"waste[{i}].kind", we.kind, wa.kind)
        exact(f"waste[{i}].nodes_a", we.nodes_a, wa.nodes_a)
        exact(f"waste[{i}].nodes_b", we.nodes_b, wa.nodes_b)
        energy(f"waste[{i}].energy_a_j", we.energy_a_j, wa.energy_a_j)
        energy(f"waste[{i}].energy_b_j", we.energy_b_j, wa.energy_b_j)
    return out


@dataclasses.dataclass
class RecordResult:
    baseline: Baseline
    report: Report
    art_a: Any                   # CandidateArtifact (live)
    art_b: Any


class BaselineStore:
    """``<root>/<case-id>.json`` expectations + ``<root>/store`` artifacts.

    The session's artifact store is forced to the baseline artifact store so
    record-time captures/compares persist (and memoize phase-2 evidence
    into) the golden artifacts that ``check --offline`` replays.

    ``artifact_store`` overrides the default ``<root>/store`` location with
    any store URI — e.g. a ``file://`` NFS mirror a fleet shares, or an
    ``http://`` readonly mirror for pure offline checks.

    By default the golden store is **sketch-only** (``sketch_only=True``):
    record persists phase-1 streamed signatures, phase-2 value digests and
    unfolding spectra, but no raw value chunks — offline replay decides
    every recorded match from the manifest alone (zero raw-value chunk
    reads), which is what keeps the committed-zoo store small.  Pass
    ``sketch_only=False`` to keep raw values (needed only if the store must
    also serve *new* comparisons offline, beyond drift replay).
    """

    def __init__(self, root: str | Path = DEFAULT_BASELINE_DIR, *,
                 session: Session | None = None,
                 artifact_store: "ArtifactStore | str | None" = None,
                 sketch_only: bool = True):
        self.root = Path(root)
        if artifact_store is None:
            self.artifacts = ArtifactStore(self.root / "store")
        else:
            self.artifacts = ArtifactStore.from_uri(artifact_store)
        self.artifacts.persist_raw_values = not sketch_only
        self.session = session or Session()
        self.session.store = self.artifacts
        # Baselines are the fidelity reference: a degraded capture or
        # sketch-only-degraded compare must never be silently recorded as
        # (or diffed against) golden truth.  Failures surface as typed
        # errors / Drift records instead of riding the degradation ladder.
        self.session.allow_degraded = False

    # -- paths / committed JSON --------------------------------------------
    def baseline_path(self, case_id: str) -> Path:
        return self.root / f"{case_id}.json"

    @property
    def index_path(self) -> Path:
        """case-id -> golden artifact keys.  Lives next to the committed
        JSON expectations (NOT inside the artifact store), so an offline
        check can point ``artifact_store`` at a shared readonly mirror that
        only carries manifests + chunks."""
        return self.root / "index.json"

    def recorded_ids(self) -> list[str]:
        if not self.root.exists():
            return []
        # index.json (case-id -> artifact keys) lives next to the per-case
        # expectations and is not a baseline itself
        return sorted(p.stem for p in self.root.glob("*.json")
                      if p.name != "index.json")

    def load(self, case_id: str) -> Baseline:
        path = self.baseline_path(case_id)
        if not path.exists():
            raise MissingBaselineError(
                f"no baseline for {case_id!r} under {self.root} — run "
                f"`python -m repro.cli baseline record {case_id}` first")
        return Baseline.from_json(path.read_text())

    def _write_json(self, path: Path, text: str) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def _load_index(self) -> dict[str, dict[str, str]]:
        if not self.index_path.exists():
            return {}
        return json.loads(self.index_path.read_text())

    def _update_index(self, case_id: str, key_a: str, key_b: str) -> None:
        idx = self._load_index()
        idx[case_id] = {"a": key_a, "b": key_b}
        self._write_json(self.index_path,
                         json.dumps(idx, indent=2, sort_keys=True))

    # -- record -------------------------------------------------------------
    def record(self, case: Case, *,
               energy_rtol: float = DEFAULT_ENERGY_RTOL) -> RecordResult:
        """Capture both twins, compare, and persist baseline + artifacts.

        The compare runs live, so every phase-2 decision the matcher made
        is persisted onto the artifacts (value digests + unfolding spectra;
        raw value chunks too unless the store is sketch-only) — the store
        can replay this exact comparison offline forever after.
        """
        if self.artifacts.readonly:
            raise BaselineError(
                "cannot record baselines into a readonly store "
                "(http mirror); record locally and `artifacts push`")
        art_a = self.session.capture(
            case.inefficient, case.make_args(), name=f"{case.id}-ineff",
            config=case.config_a,
            extra_meta={"zoo_case": case.id, "zoo_side": "ineff"})
        art_b = self.session.capture(
            case.efficient, case.make_args(), name=f"{case.id}-eff",
            config=case.config_b,
            extra_meta={"zoo_case": case.id, "zoo_side": "eff"})
        report = self.session.compare(art_a, art_b,
                                      output_rtol=case.output_rtol)
        baseline = Baseline.from_report(
            case, report, backend_id=self.session.backend.id,
            sample_seeds=art_a.sample_seeds, energy_rtol=energy_rtol)
        self._write_json(self.baseline_path(case.id), baseline.to_json())
        self._update_index(case.id, art_a.key, art_b.key)
        return RecordResult(baseline=baseline, report=report,
                            art_a=art_a, art_b=art_b)

    def record_all(self, cases: Sequence[Case], *,
                   energy_rtol: float = DEFAULT_ENERGY_RTOL
                   ) -> dict[str, RecordResult]:
        return {c.id: self.record(c, energy_rtol=energy_rtol) for c in cases}

    # -- check --------------------------------------------------------------
    def _offline_artifacts(self, case: Case):
        idx = self._load_index().get(case.id)
        if idx is None:
            raise BaselineError(
                f"{case.id}: no golden artifacts in {self.artifacts.root} — "
                "run `baseline record` (or a live `baseline check`) to "
                "populate the store before checking offline")
        try:
            return self.artifacts.load(idx["a"]), self.artifacts.load(idx["b"])
        except KeyError as e:
            raise BaselineError(
                f"{case.id}: golden artifact missing from store "
                f"({e.args[0]}); re-run `baseline record`") from None

    def check(self, case: Case, *, offline: bool = False) -> list[Drift]:
        """Replay one case and diff the findings against its baseline.

        ``offline=True`` loads the golden artifacts and never executes the
        candidates (the loaded artifacts are not even re-attached, so any
        attempted instrumented execution would raise).
        """
        expected = self.load(case.id)
        try:
            if offline:
                art_a, art_b = self._offline_artifacts(case)
            else:
                art_a = self.session.capture(
                    case.inefficient, case.make_args(),
                    name=f"{case.id}-ineff", config=case.config_a,
                    sample_seeds=expected.sample_seeds,
                    extra_meta={"zoo_case": case.id, "zoo_side": "ineff"})
                art_b = self.session.capture(
                    case.efficient, case.make_args(), name=f"{case.id}-eff",
                    config=case.config_b,
                    sample_seeds=expected.sample_seeds,
                    extra_meta={"zoo_case": case.id, "zoo_side": "eff"})
                # a live check (re)populates the golden store, so a
                # subsequent offline replay can run against exactly what was
                # just checked
                self._update_index(case.id, art_a.key, art_b.key)
        except StoreError as e:
            # a corrupt/unreachable golden store (the session is strict:
            # allow_degraded=False, so it surfaces instead of degrading) is
            # declared as drift — the check did NOT reproduce the baseline
            # and CI must say why
            return [Drift(case.id, "store",
                          "golden store reachable and intact",
                          f"{type(e).__name__}: {e}")]
        if art_a.backend_id != expected.backend_id:
            return [Drift(case.id, "backend_id", expected.backend_id,
                          art_a.backend_id)]
        try:
            report = self.session.compare(art_a, art_b,
                                          output_rtol=case.output_rtol)
        except ArtifactValueError as e:
            # the record-time compare memoized exactly the values a
            # bit-identical replay fetches, so needing MORE values IS
            # changed matcher behavior — report it as drift, never as
            # advice to re-record (that would bless the change unseen)
            return [Drift(case.id, "offline_replay",
                          "all phase-2 fetches served from the golden store",
                          f"unmaterialized fetch: {e}")]
        except StoreError as e:
            # a corrupt/unreachable golden store is declared as drift, not
            # silently degraded around: the check did NOT reproduce the
            # baseline and CI must say why
            return [Drift(case.id, "store",
                          "golden store reachable and intact",
                          f"{type(e).__name__}: {e}")]
        actual = Baseline.from_report(
            case, report, backend_id=art_a.backend_id,
            sample_seeds=art_a.sample_seeds, energy_rtol=expected.energy_rtol)
        return diff_baselines(expected, actual)

    def check_all(self, cases: Sequence[Case], *, offline: bool = False
                  ) -> dict[str, list[Drift]]:
        return {c.id: self.check(c, offline=offline) for c in cases}
