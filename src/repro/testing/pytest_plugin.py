"""Pytest plugin: gate any model/kernel on a recorded energy baseline.

Loaded via ``pytest_plugins = ["repro.testing.pytest_plugin"]`` (this repo's
``tests/conftest.py`` does) or ``-p repro.testing.pytest_plugin``.  Two
surfaces:

* :func:`assert_no_energy_regression` — capture a candidate and fail the
  test if it spends more energy than its recorded baseline artifact, either
  in total (beyond ``energy_rtol``) or in any confirmed waste region of the
  differential comparison.  Missing baselines fail with instructions; set
  ``MAGNETON_RECORD_BASELINES=1`` (or pass ``record=True``) to record them.
* the ``energy_regression`` marker — tags energy-gate tests so they can be
  selected (``-m energy_regression``) or skipped (``-m "not
  energy_regression"``) as a suite, and lets ``--energy-record`` flip every
  gate in the run into record mode at once.

Typical in-suite gate::

    @pytest.mark.energy_regression
    def test_rmsnorm_energy(energy_gate):
        x, w = make_inputs()
        energy_gate(my_rmsnorm, (x, w), baseline="rmsnorm_256x512")

The baseline name resolves to ``<baseline-dir>/kernels/<name>.npz`` (a
serialized :class:`~repro.core.artifact.CandidateArtifact` with all tensor
values materialized, so the differential comparison replays offline).
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Callable, Sequence

import pytest

_RECORD_ENV = "MAGNETON_RECORD_BASELINES"
_DIR_ENV = "MAGNETON_BASELINE_DIR"
_STRICT_ENV = "MAGNETON_ENERGY_STRICT"
_DEFAULT_DIR = "tests/baselines"
_KERNEL_SUBDIR = "kernels"


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "energy_regression: energy-baseline gate (select with "
        "'-m energy_regression'; record baselines with --energy-record or "
        f"{_RECORD_ENV}=1)")


def pytest_addoption(parser):
    group = parser.getgroup("magneton")
    group.addoption(
        "--energy-record", action="store_true", default=False,
        help="record missing/changed energy baselines instead of failing")
    group.addoption(
        "--energy-strict", action="store_true", default=False,
        help="treat an unreachable/unreadable baseline store as a test "
             "FAILURE; the default skips the gate with the store error as "
             f"the reason (also {_STRICT_ENV}=1)")
    parser.addini("energy_baseline_dir", default=_DEFAULT_DIR,
                  help="root directory for recorded energy baselines")


def _baseline_dir(config) -> Path:
    env = os.environ.get(_DIR_ENV)
    return Path(env) if env else Path(config.getini("energy_baseline_dir"))


@pytest.fixture
def energy_baseline_dir(request) -> Path:
    return _baseline_dir(request.config)


@pytest.fixture
def energy_gate(request, energy_baseline_dir) -> Callable:
    """:func:`assert_no_energy_regression` bound to the configured baseline
    dir and the ``--energy-record`` flag."""
    record = bool(request.config.getoption("--energy-record")
                  or os.environ.get(_RECORD_ENV))
    strict = bool(request.config.getoption("--energy-strict")
                  or os.environ.get(_STRICT_ENV))

    def gate(fn, args, *, baseline: str, **kw):
        kw.setdefault("record", record)
        kw.setdefault("strict", strict)
        kw.setdefault("baseline_dir", energy_baseline_dir)
        return assert_no_energy_regression(fn, args, baseline, **kw)

    return gate


def _resolve_baseline(baseline: str | Path, baseline_dir: str | Path | None
                      ) -> Path:
    p = Path(baseline)
    if p.suffix == ".npz":                  # explicit path
        return p
    root = Path(baseline_dir) if baseline_dir is not None \
        else Path(os.environ.get(_DIR_ENV, _DEFAULT_DIR))
    return root / _KERNEL_SUBDIR / f"{p}.npz"


def _store_unavailable(what: str, exc: BaseException, strict: bool):
    """Unreachable/unreadable baseline store: skip by default, fail under
    ``--energy-strict``.  Never lets the gate pass silently."""
    msg = (f"energy baseline store unavailable while {what}: "
           f"{type(exc).__name__}: {exc}")
    if strict:
        pytest.fail(msg + " (--energy-strict)", pytrace=False)
    pytest.skip(msg + "; pass --energy-strict to fail instead")


def assert_no_energy_regression(fn: Callable, args: Sequence[Any],
                                baseline: str | Path, *,
                                name: str | None = None,
                                session=None,
                                energy_rtol: float = 0.05,
                                output_rtol: float = 1e-2,
                                record: bool | None = None,
                                strict: bool | None = None,
                                baseline_dir: str | Path | None = None):
    """Fail (via ``pytest.fail``) if ``fn`` regressed vs its baseline.

    The baseline is a recorded :class:`CandidateArtifact`; the check is
    differential, not a bare wattmeter read: the fresh capture and the
    baseline run through ``Session.compare``, so a regression is reported
    with the wasteful region, root cause, and energy delta — and an
    *improvement* (the new side cheaper) passes, updating nothing.

    Returns the comparison :class:`~repro.core.report.Report` (``None``
    when the baseline was just recorded or the capture is bit-identical).
    """
    from repro.core.artifact import CandidateArtifact
    from repro.core.store import StoreError

    from repro.core.session import Session

    path = _resolve_baseline(baseline, baseline_dir)
    if record is None:
        record = bool(os.environ.get(_RECORD_ENV))
    if strict is None:
        strict = bool(os.environ.get(_STRICT_ENV))
    session = session or Session()
    name = name or getattr(fn, "__name__", "candidate")

    if record:
        # record mode (re)blesses the CURRENT implementation — missing
        # baselines are created and existing ones overwritten, so an
        # intentional energy change is accepted by re-running with the flag
        art = session.capture(fn, args, name=name)
        art.materialize()               # offline-replayable golden artifact
        try:
            art.save(path)
        except (StoreError, OSError) as e:
            _store_unavailable(f"recording baseline {path}", e, strict)
        return None
    if not path.exists():
        pytest.fail(
            f"no energy baseline at {path} for {name!r}; record it with "
            f"{_RECORD_ENV}=1 (or --energy-record) and commit the file",
            pytrace=False)

    try:
        base = CandidateArtifact.load(path)
    except (StoreError, OSError) as e:
        # the file exists but can't be read (dead mount, permissions,
        # directory-in-place-of-file) — an infrastructure problem, not an
        # energy regression
        _store_unavailable(f"loading baseline {path}", e, strict)
    if base.backend_id != session.backend.id:
        pytest.fail(
            f"baseline {path} was priced by backend {base.backend_id!r} but "
            f"the session uses {session.backend.id!r}; re-record the "
            "baseline or pass a matching session", pytrace=False)
    try:
        art = session.capture(fn, args, name=name,
                              sample_seeds=base.sample_seeds)
    except StoreError as e:
        # session artifact store down and the session is strict
        # (allow_degraded=False); only StoreError — an OSError here could
        # come from the candidate fn itself and must stay a real failure
        _store_unavailable(f"capturing candidate {name!r}", e, strict)
    if art.key == base.key:
        return None                     # bit-identical capture: no drift

    problems: list[str] = []
    if art.total_energy_j > base.total_energy_j * (1.0 + energy_rtol):
        pct = (art.total_energy_j / base.total_energy_j - 1.0) * 100.0
        problems.append(
            f"total modeled energy regressed {pct:+.1f}% "
            f"({base.total_energy_j:.4e} J -> {art.total_energy_j:.4e} J, "
            f"tolerance {energy_rtol:.1%})")
    try:
        report = session.compare(art, base, output_rtol=output_rtol)
    except StoreError as e:
        _store_unavailable(f"comparing {name!r} against {path}", e, strict)
    regressions = [f for f in report.waste_findings if f.wasteful_side == "A"]
    for f in regressions:
        diag = f.diagnosis
        problems.append(
            f"region {f.region_idx}: new implementation wastes "
            f"{f.energy_a_j - f.energy_b_j:.3e} J "
            f"(+{f.energy_delta_pct:.1f}%)"
            + (f" — {diag.kind}: {diag.detail}" if diag else ""))
    if problems:
        pytest.fail(f"energy regression in {name!r} vs baseline {path}:\n  "
                    + "\n  ".join(problems), pytrace=False)
    return report
