"""Energy-regression testing subsystem (built on Session/CandidateArtifact).

Magneton's value claim is detection *quality*: waste pinpointed at operator
level with a correct root cause.  This package turns that claim into an
automated, repeatable harness (MLPerf-Power-style) with three legs:

* **Golden baselines** (:mod:`repro.testing.baselines`): every zoo case is
  captured once into a content-addressed artifact store plus a committed
  JSON expectation (detected?, waste sign, root-cause class, energies with
  declared tolerances).  ``python -m repro.cli baseline record/check``
  records and replays them; ``check --offline`` re-runs the comparison from
  the persisted artifacts with zero instrumented execution, so finding
  drift is caught even on machines that cannot run the candidates.

* **A pytest plugin** (:mod:`repro.testing.pytest_plugin`): exposes
  :func:`assert_no_energy_regression` and an ``energy_regression`` marker so
  any model/kernel in ``src/repro`` can be gated in-suite against a recorded
  baseline artifact.

* **A mutation engine** (:mod:`repro.testing.mutate`): programmatically
  injects the paper's waste patterns (dtype upcast, redundant recompute,
  sync-in-loop, oversized padding, eager-vs-fused op splits) into clean
  jaxprs from ``models/`` and ``kernels/`` and asserts the debugger detects
  and correctly classifies each injected mutant — detector validation over a
  *generated* scenario space instead of 20 fixed twins.
"""

from repro.testing.baselines import (Baseline, BaselineError, BaselineStore,
                                     Drift, MissingBaselineError,
                                     diff_baselines)
from repro.testing.mutate import (MUTATIONS, CleanProgram, DtypeUpcast,
                                  InapplicableMutationError, Mutation,
                                  OpSplit, OversizedPadding,
                                  RedundantRecompute, Scenario, SyncInLoop,
                                  ValidationResult, clean_programs,
                                  generate_scenarios, make_mutant,
                                  validate_detector)


def __getattr__(name):
    # pytest_plugin imports pytest at module scope; load it lazily so
    # pytest-free consumers (the CLI's `baseline` commands, library users of
    # the baseline/mutation APIs) never pay that dependency
    if name == "assert_no_energy_regression":
        from repro.testing.pytest_plugin import assert_no_energy_regression
        return assert_no_energy_regression
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Baseline", "BaselineError", "BaselineStore", "Drift",
    "MissingBaselineError", "diff_baselines",
    "MUTATIONS", "CleanProgram", "DtypeUpcast", "InapplicableMutationError",
    "Mutation", "OpSplit",
    "OversizedPadding", "RedundantRecompute", "Scenario", "SyncInLoop",
    "ValidationResult", "clean_programs", "generate_scenarios", "make_mutant",
    "validate_detector",
    "assert_no_energy_regression",
]
