"""Logical-axis sharding rules with divisibility fallbacks.

Every parameter and boundary activation in the model zoo is annotated with
*logical* axis names; this module maps them onto the physical mesh.  A rule
lists candidate mesh-axis tuples in priority order; the first candidate whose
product divides the dimension is used, so the same model code shards
correctly on the 16x16 single-pod mesh, the (2,16,16) multi-pod mesh, and the
1..8-device CPU meshes used in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> candidate mesh-axis assignments, in priority order.
# each candidate is a tuple of mesh axis names (compounded), or () = replicate.
DEFAULT_RULES: dict[str, list[tuple[str, ...]]] = {
    # activations
    "batch": [("pod", "data"), ("data",), ()],
    "seq": [()],                      # sequence dim of train activations
    "seq_sp": [("model",), ()],       # sequence-parallel layer boundaries
    "kv_seq": [("data", "model"), ("model",), ()],  # decode-cache seq dim
    "embed_act": [()],                # d_model dim of activations
    # params
    "vocab": [("model",), ()],
    "embed": [("pod", "data"), ("data",), ()],      # FSDP dim of params
    "heads": [("model",), ()],
    "kv_heads": [("model",), ()],
    "head_dim": [()],
    "ffn": [("model",), ()],
    "experts": [("model",), ()],
    "expert_ffn": [()],
    "ssm_inner": [("model",), ()],
    "ssm_state": [()],
    "stack": [()],                    # scan-stacked layer dim
    None: [()],
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict | None = None

    def _mesh_axes(self, mesh: Mesh, logical: str | None, dim: int,
                   taken: set[str]) -> tuple[str, ...] | None:
        table = self.rules or DEFAULT_RULES
        candidates = table.get(logical, [()])
        for cand in candidates:
            if any(a not in mesh.axis_names for a in cand):
                continue
            if any(a in taken for a in cand):
                continue
            size = int(np.prod([mesh.shape[a] for a in cand], dtype=np.int64)) \
                if cand else 1
            if size == 1 and cand:
                continue
            if cand and dim % size != 0:
                continue
            return cand
        return ()

    def spec(self, mesh: Mesh, logical_axes: Sequence[str | None],
             shape: Sequence[int]) -> P:
        """PartitionSpec for a tensor with the given logical axes and shape."""
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        taken: set[str] = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            axes = self._mesh_axes(mesh, name, int(dim), taken)
            if not axes:
                parts.append(None)
            else:
                taken.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, mesh: Mesh, logical_axes: Sequence[str | None],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(mesh, self.spec(mesh, logical_axes, shape))


GLOBAL_RULES = ShardingRules()

# Hybrid-FSDP preset (beyond-paper §Perf lever for DCN-bound multi-pod
# cells): parameters FSDP-shard only WITHIN a pod ("data" axis) and
# replicate across pods, so the per-layer parameter all-gathers ride the
# ICI; only the once-per-step gradient all-reduce crosses the DCN.
# Costs params*pods extra HBM; wins when the DCN collective term dominates.
POD_LOCAL_FSDP_RULES = dict(DEFAULT_RULES)
POD_LOCAL_FSDP_RULES["embed"] = [("data",), ()]
POD_LOCAL_FSDP_RULES["batch"] = [("pod", "data"), ("data",), ()]

_PRESETS = {"global-fsdp": DEFAULT_RULES, "pod-fsdp": POD_LOCAL_FSDP_RULES}


def set_sharding_preset(name: str) -> None:
    """Swap the global rule table (affects all subsequent spec lookups)."""
    GLOBAL_RULES.__dict__["rules"] = dict(_PRESETS[name])


def constrain(x, mesh: Mesh | None, logical_axes: Sequence[str | None],
              rules: ShardingRules = GLOBAL_RULES):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    if mesh is None or mesh.empty or np.prod(list(mesh.shape.values())) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(mesh, logical_axes, x.shape))
