"""Deterministic sampling policies for live audit captures.

Three triggers, all seeded/deterministic so tests can replay a traffic
trace and get the identical sample schedule:

* **every-Nth** — per-class counters fire every ``every`` observations.
  Each class gets a seeded phase offset in ``[0, every)`` so a fleet's
  classes don't all audit on the same wave.
* **latency-SLO headroom** — with ``slo_ms`` set, a cadence firing is only
  *taken* when the observed step latency leaves headroom under the SLO
  (``latency <= headroom * slo``): audits piggyback on quiet periods and
  never pile onto a request already near its deadline.  Pressured firings
  are counted (``slo_skipped``) and the cadence moves on — deterministic,
  no rescheduling.  With ``every == 0`` the headroom test itself is the
  trigger, rate-limited by a per-class refractory gap.
* **forced on config change** — a class whose engine-config fingerprint
  changed since its last observation fires immediately, regardless of
  cadence: a redeploy must be drift-checked now, not ``N`` requests later.
"""

from __future__ import annotations

import dataclasses
import hashlib

REASONS = ("every_n", "slo_headroom", "config_change")


@dataclasses.dataclass(frozen=True)
class SampleDecision:
    sample: bool
    reason: str | None = None         # one of REASONS when sample is True


class Sampler:
    """Per-class deterministic sample scheduling (see module docstring)."""

    def __init__(self, every: int = 0, slo_ms: float | None = None,
                 headroom: float = 0.5, seed: int = 0, slo_gap: int = 32):
        if every < 0:
            raise ValueError(f"every must be >= 0, got {every}")
        self.every = int(every)
        self.slo_ms = slo_ms
        self.headroom = float(headroom)
        self.seed = int(seed)
        self.slo_gap = int(slo_gap)
        self.counts: dict[str, int] = {}       # observations per class
        self.sampled: dict[str, int] = {}      # taken samples per class
        self.slo_skipped = 0                   # cadence firings under pressure
        self._fingerprints: dict[str, str] = {}
        self._last_sample_at: dict[str, int] = {}

    def _phase(self, class_key: str) -> int:
        """Seeded per-class offset so classes don't fire in lockstep."""
        h = hashlib.sha256(f"{self.seed}:{class_key}".encode()).digest()
        return int.from_bytes(h[:4], "big") % self.every

    def _headroom_ok(self, latency_s: float | None) -> bool:
        if self.slo_ms is None or latency_s is None:
            return True
        return latency_s * 1e3 <= self.headroom * self.slo_ms

    def _take(self, class_key: str, reason: str) -> SampleDecision:
        self.sampled[class_key] = self.sampled.get(class_key, 0) + 1
        self._last_sample_at[class_key] = self.counts[class_key]
        return SampleDecision(True, reason)

    def observe(self, class_key: str, *, latency_s: float | None = None,
                fingerprint: str | None = None) -> SampleDecision:
        """Advance this class's schedule by one observation and decide."""
        n = self.counts.get(class_key, 0)
        self.counts[class_key] = n + 1

        if fingerprint is not None:
            prev = self._fingerprints.get(class_key)
            self._fingerprints[class_key] = fingerprint
            if prev is not None and prev != fingerprint:
                return self._take(class_key, "config_change")

        if self.every > 0:
            if n % self.every != self._phase(class_key):
                return SampleDecision(False)
            if not self._headroom_ok(latency_s):
                self.slo_skipped += 1
                return SampleDecision(False)
            return self._take(class_key, "every_n")

        if self.slo_ms is not None:
            since = n - self._last_sample_at.get(class_key, -self.slo_gap)
            if since >= self.slo_gap and self._headroom_ok(latency_s):
                return self._take(class_key, "slo_headroom")
        return SampleDecision(False)

    def to_payload(self) -> dict:
        """JSON-safe snapshot for audit manifests / ``health()``."""
        return {"every": self.every, "slo_ms": self.slo_ms,
                "headroom": self.headroom, "seed": self.seed,
                "counts": dict(self.counts), "sampled": dict(self.sampled),
                "slo_skipped": self.slo_skipped}
