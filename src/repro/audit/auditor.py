"""EngineAuditor: per-request-class baselines and continuous drift checks.

The auditor owns the live-audit state for one serving engine: a
deterministic :class:`~repro.audit.sampler.Sampler`, an
:class:`~repro.audit.log.AuditLog`, the per-class artifact lineage, and
the connection to a (possibly shared, writable-remote) fleet store.

Drift semantics — each request class is checked against its *own* golden
baseline:

* The golden is a reserved ``audit-class--<digest>`` manifest keyed by
  ``sha256(class_key | config_fingerprint | backend_id)``.  It names the
  golden's content-addressed artifact key and modeled energy — and
  deliberately NOT the engine that wrote it, so two identical engines
  racing to elect a golden write byte-identical records (a benign
  last-writer-wins race under the conditional-put dialect).
* Because probe inputs are canonical and seeded from the class key, an
  unchanged engine re-captures the *same* artifact key as the golden —
  drift checks on a healthy engine are cache hits, no compare needed.
* A changed engine captures a different key; the auditor loads the golden
  artifact and runs the ordinary sketch-capable offline
  ``session.compare(golden, fresh)``.  An alarm fires when the fresh side
  is the confirmed-wasteful side or its modeled energy regresses beyond
  ``energy_rtol`` — and it carries the full :class:`Diagnosis` (kind,
  deviation point, priced_by, degraded mark), not just a scalar delta.

Every sampled event lands in the audit log, which is flushed whole to the
store as the engine's ``audit--<engine_id>`` manifest — immediately for
check/alarm/error events, batched per ``flush_every`` for lightweight
captures, with a final flush when the engine drains.  A failed flush
keeps the events in memory for the next attempt (no lost samples, per the
graceful-degradation ladder).
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Any, Callable

from repro.audit.classes import RequestClass, classify
from repro.audit.log import AuditEvent, AuditLog
from repro.audit.sampler import SampleDecision, Sampler
from repro.core.report import Report
from repro.core.session import Session
from repro.core.store import StoreError
from repro.testing.baselines import rel_diff

GOLDEN_SCHEMA = 1
GOLDEN_PREFIX = "audit-class--"
LOG_PREFIX = "audit--"


def sanitize_id(engine_id: str) -> str:
    """Engine ids become manifest-key components; keep them path-safe."""
    return re.sub(r"[^A-Za-z0-9._-]+", "-", engine_id).strip("-") or "engine"


def golden_key(class_key: str, fingerprint: str, backend_id: str) -> str:
    digest = hashlib.sha256(
        f"{class_key}|{fingerprint}|{backend_id}".encode()).hexdigest()
    return f"{GOLDEN_PREFIX}{digest[:20]}"


def log_key(engine_id: str) -> str:
    return f"{LOG_PREFIX}{sanitize_id(engine_id)}"


@dataclasses.dataclass
class AuditConfig:
    """Knobs for one engine's live auditing (threaded from launch flags)."""

    engine_id: str = "engine"
    store: str | None = None         # fleet store URI; None = in-memory only
    sample_every: int = 0            # every-Nth cadence (0 = off)
    slo_ms: float | None = None      # latency SLO for headroom gating
    slo_headroom: float = 0.5
    seed: int = 0
    energy_rtol: float = 0.05        # relative energy drift that alarms
    # 0: one full drift check per class per process (later samples are
    # lightweight log events — keeps amortized overhead tiny); N>0: a full
    # re-check every N samples of that class.  Config changes always force
    # a full check regardless.
    recheck_every: int = 0
    log_capacity: int = 256
    # lightweight capture events are batched: the log flushes to the store
    # immediately on check/alarm/error events, but only every N captures
    # (plus a final flush at end-of-serve) — keeps the steady-state sampled
    # path at ring-append cost instead of a store write per sample
    flush_every: int = 8
    store_timeout: float | None = None


@dataclasses.dataclass(frozen=True)
class DriftAlarm:
    """One confirmed per-class drift, carrying the diagnosis."""

    class_key: str
    energy_delta: float              # (fresh - golden) / golden, signed
    diagnosis_kind: str | None       # Diagnosis.kind, when one was produced
    detail: str
    degraded: bool                   # check ran on a degradation-ladder rung

    def to_payload(self) -> dict:
        return dataclasses.asdict(self)


class EngineAuditor:
    """Live-audit state machine for one engine (see module docstring).

    ``probe_factory(rc)`` must return ``(fn, args, config)`` — the
    canonical, seeded probe for a request class.  It is engine-supplied
    (:meth:`repro.serve.engine.ServeEngine._audit_probe`) so the auditor
    stays model-agnostic.
    """

    def __init__(self, probe_factory: Callable[[RequestClass], tuple],
                 fingerprint: str, cfg: AuditConfig | None = None, *,
                 session: Session | None = None):
        self.cfg = cfg if cfg is not None else AuditConfig()
        self.probe_factory = probe_factory
        self.fingerprint = fingerprint
        if session is not None:
            self.session = session
        elif self.cfg.store is not None:
            self.session = Session(store=self.cfg.store, store_writable=True)
        else:
            self.session = Session()
        self.sampler = Sampler(every=self.cfg.sample_every,
                               slo_ms=self.cfg.slo_ms,
                               headroom=self.cfg.slo_headroom,
                               seed=self.cfg.seed)
        self.log = AuditLog(capacity=self.cfg.log_capacity)
        self.alarms: list[DriftAlarm] = []
        self.flush_failures = 0
        self.last_error: str | None = None
        # per-class lineage: samples since the last full drift check, and
        # in-memory goldens for store-less operation
        self._since_check: dict[str, int] = {}
        self._local_goldens: dict[str, dict] = {}
        self._unflushed = 0

    # -- scheduling ---------------------------------------------------------
    def observe(self, phase: str, batch: int, seq_len: int, *,
                latency_s: float | None = None
                ) -> tuple[RequestClass, SampleDecision]:
        """Classify one engine step and advance its sample schedule."""
        rc = classify(phase, batch, seq_len)
        dec = self.sampler.observe(rc.key, latency_s=latency_s,
                                   fingerprint=self.fingerprint)
        return rc, dec

    # -- the sampled path (runs inside the engine's watchdog boundary) ------
    def sample(self, rc: RequestClass, reason: str, *,
               latency_s: float | None = None) -> AuditEvent:
        """Take one scheduled sample: a full drift check when due, a
        lightweight log event otherwise.  Check/alarm/error events flush
        the log immediately; captures are batched per ``flush_every``."""
        due = self._since_check.get(rc.key)
        full = (due is None                          # first sample of class
                or reason == "config_change"         # redeploy: check now
                or (self.cfg.recheck_every > 0
                    and due + 1 >= self.cfg.recheck_every))
        try:
            if full:
                ev = self._drift_check(rc, reason, latency_s=latency_s)
                self._since_check[rc.key] = 0
            else:
                ev = self.log.record(rc.key, reason, "capture",
                                     latency_s=latency_s)
                self._since_check[rc.key] = due + 1
        except Exception as e:
            self.last_error = f"{type(e).__name__}: {e}"
            ev = self.log.record(rc.key, reason, "error",
                                 latency_s=latency_s,
                                 detail=self.last_error)
            self.flush()
            raise
        self._unflushed += 1
        if ev.kind != "capture" or \
                self._unflushed >= max(1, self.cfg.flush_every):
            self.flush()
        return ev

    def _drift_check(self, rc: RequestClass, reason: str, *,
                     latency_s: float | None) -> AuditEvent:
        fn, args, config = self.probe_factory(rc)
        art = self.session.capture(
            fn, args, name=f"audit:{rc.key}", config=config,
            extra_meta={"audit_class": rc.key,
                        "audit_fingerprint": self.fingerprint})
        golden, elected = self._load_or_elect_golden(rc, art)

        if elected or golden["artifact_key"] == art.key:
            # healthy fast path: the fresh capture IS the golden lineage
            # (content-addressed identity) — zero drift by construction
            return self.log.record(rc.key, reason, "check",
                                   latency_s=latency_s, energy_delta=0.0,
                                   degraded=bool(art.meta.get("degraded")))

        report = self._compare_to_golden(golden, art)
        fresh_j = art.profile.total_energy_j
        golden_j = float(golden.get("energy_j", report.total_energy_a_j))
        delta = ((fresh_j - golden_j) / golden_j if golden_j > 0
                 else (0.0 if fresh_j <= 0 else float("inf")))
        fresh_waste = [f for f in report.waste_findings
                       if f.wasteful_side == "B"]
        regressed = (fresh_j > golden_j
                     and rel_diff(fresh_j, golden_j) > self.cfg.energy_rtol)
        alarming = bool(fresh_waste) or regressed
        if not alarming:
            return self.log.record(rc.key, reason, "check",
                                   latency_s=latency_s, energy_delta=delta,
                                   degraded=report.is_degraded)

        diag = next((f.diagnosis for f in fresh_waste
                     if f.diagnosis is not None), None)
        detail = (diag.detail if diag is not None else
                  f"modeled energy regressed {delta:+.1%} vs golden "
                  f"(rtol {self.cfg.energy_rtol:g})")
        alarm = DriftAlarm(class_key=rc.key, energy_delta=delta,
                           diagnosis_kind=diag.kind if diag else None,
                           detail=detail, degraded=report.is_degraded)
        self.alarms.append(alarm)
        return self.log.record(rc.key, reason, "alarm",
                               latency_s=latency_s, energy_delta=delta,
                               diagnosis_kind=alarm.diagnosis_kind,
                               detail=detail, degraded=report.is_degraded)

    def _compare_to_golden(self, golden: dict,
                           art) -> Report:
        golden_art = self.session.load(golden["artifact_key"])
        # the drift check must never mutate the golden record, so compare
        # golden as side A / fresh as side B and skip persisting phase-2
        # values back (the fresh artifact was already saved by capture)
        return self.session.compare(golden_art, art, persist=False)

    # -- golden election ----------------------------------------------------
    def _golden_key(self, rc: RequestClass) -> str:
        return golden_key(rc.key, self.fingerprint, self.session.backend.id)

    def _load_or_elect_golden(self, rc: RequestClass,
                              art) -> tuple[dict, bool]:
        """Return (golden record, whether this call elected it)."""
        record = {"schema": GOLDEN_SCHEMA, "class_key": rc.key,
                  "fingerprint": self.fingerprint,
                  "backend_id": self.session.backend.id,
                  "artifact_key": art.key,
                  "energy_j": art.profile.total_energy_j}
        key = self._golden_key(rc)
        store = self.session.store
        if store is None:
            golden = self._local_goldens.setdefault(key, record)
            return golden, golden is record
        try:
            if store.backend.has_manifest(key):
                return store.backend.read_manifest(key), False
            store.backend.write_manifest(key, record)
            return record, True
        except (StoreError, OSError) as e:
            # store unreachable: fall back to the in-process golden so the
            # check still runs; declared via last_error, never raises
            self.last_error = f"golden election degraded: " \
                              f"{type(e).__name__}: {e}"
            golden = self._local_goldens.setdefault(key, record)
            return golden, golden is record

    # -- persistence --------------------------------------------------------
    def flush(self) -> bool:
        """Write the whole audit log to the fleet store.  Returns False
        (and keeps every event in memory) when the store is absent or the
        write fails — the next flush retries with nothing lost."""
        store = self.session.store
        if store is None:
            return False
        payload = self.to_payload()
        try:
            store.backend.write_manifest(log_key(self.cfg.engine_id), payload)
            self._unflushed = 0
            return True
        except (StoreError, OSError) as e:
            self.flush_failures += 1
            self.last_error = f"log flush failed: {type(e).__name__}: {e}"
            return False

    def to_payload(self) -> dict:
        """The engine's ``audit--`` manifest body (JSON-safe)."""
        return {"schema": GOLDEN_SCHEMA,
                "engine_id": self.cfg.engine_id,
                "fingerprint": self.fingerprint,
                "sampler": self.sampler.to_payload(),
                "log": self.log.to_payload(),
                "alarms": [a.to_payload() for a in self.alarms],
                "flush_failures": self.flush_failures,
                "last_error": self.last_error,
                # recurring per-class checks replay unchanged blocks from
                # the evidence cache; the fleet dashboard aggregates these
                "block_cache": self.session.block_cache_counters}

    def summary(self) -> dict[str, Any]:
        """Compact JSON-safe health summary for ``ServeEngine.health()``."""
        return {"classes": sorted(self.sampler.counts),
                "observed": sum(self.sampler.counts.values()),
                "sampled": sum(self.sampler.sampled.values()),
                "slo_skipped": self.sampler.slo_skipped,
                "alarms": self.log.alarm_count(),
                "flush_failures": self.flush_failures,
                "last_error": self.last_error,
                "block_cache": self.session.block_cache_counters}
