"""Request-class keying: phase × batch bucket × sequence-length bucket.

A serving engine never audits individual requests — it audits *classes* of
traffic, each with its own golden baseline and artifact lineage.  A class
is (phase, batch bucket, sequence-length bucket) with power-of-two buckets,
so an engine serving mixed prompt lengths accumulates a handful of stable
classes instead of one artifact key per request shape.

The class key doubles as the canonical-probe seed (the auditor derives a
deterministic probe input from it), so every engine in a fleet that sees
the same class under the same config captures the *same* content-addressed
artifact — the property that makes cross-engine golden sharing and
conditional-put convergence work.  Key schema (docs/serving.md)::

    <phase>/b<batch_floor>/s<seq_lo>-<seq_hi>     e.g.  decode/b4/s32-63
"""

from __future__ import annotations

import dataclasses
import re

PHASES = ("prefill", "decode")

_KEY_RE = re.compile(r"^(prefill|decode)/b(\d+)/s(\d+)-(\d+)$")


def pow2_bucket(n: int) -> tuple[int, int]:
    """The power-of-two bucket ``[lo, 2*lo - 1]`` containing ``n >= 1``."""
    n = max(1, int(n))
    lo = 1
    while lo * 2 <= n:
        lo *= 2
    return lo, lo * 2 - 1


@dataclasses.dataclass(frozen=True, order=True)
class RequestClass:
    """One traffic class: the unit of golden baselines and drift alarms."""

    phase: str                       # 'prefill' | 'decode'
    batch: int                       # batch bucket floor (power of two)
    seq_lo: int                      # sequence-length bucket [lo, hi]
    seq_hi: int

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"phase must be one of {PHASES}, "
                             f"got {self.phase!r}")

    @property
    def key(self) -> str:
        return f"{self.phase}/b{self.batch}/s{self.seq_lo}-{self.seq_hi}"

    # canonical probe shape: the bucket floor on both axes, so every
    # request that lands in this class maps to one deterministic capture
    @property
    def probe_batch(self) -> int:
        return self.batch

    @property
    def probe_seq_len(self) -> int:
        return self.seq_lo

    @classmethod
    def from_key(cls, key: str) -> "RequestClass":
        m = _KEY_RE.match(key)
        if m is None:
            raise ValueError(f"malformed request-class key {key!r} "
                             "(want <phase>/b<batch>/s<lo>-<hi>)")
        return cls(phase=m.group(1), batch=int(m.group(2)),
                   seq_lo=int(m.group(3)), seq_hi=int(m.group(4)))


def classify(phase: str, batch: int, seq_len: int) -> RequestClass:
    """Map one observed engine step onto its request class."""
    blo, _ = pow2_bucket(batch)
    slo, shi = pow2_bucket(seq_len)
    return RequestClass(phase=phase, batch=blo, seq_lo=slo, seq_hi=shi)
