"""Always-on sampled energy auditing for live serving (docs/serving.md).

The subsystem that turns a serving engine into a self-auditing service:
deterministic sampling policies (:mod:`repro.audit.sampler`), request-class
keying with per-class golden baselines and drift alarms
(:mod:`repro.audit.classes`, :mod:`repro.audit.auditor`), a bounded audit
log (:mod:`repro.audit.log`), and cross-engine fleet aggregation over a
shared writable store (:mod:`repro.audit.fleet`).
"""

from repro.audit.auditor import (AuditConfig, DriftAlarm, EngineAuditor,
                                 golden_key, log_key, sanitize_id)
from repro.audit.classes import (PHASES, RequestClass, classify, pow2_bucket)
from repro.audit.fleet import fleet_status, render_fleet_status
from repro.audit.log import AuditEvent, AuditLog
from repro.audit.sampler import REASONS, SampleDecision, Sampler

__all__ = [
    "AuditConfig", "AuditEvent", "AuditLog", "DriftAlarm", "EngineAuditor",
    "PHASES", "REASONS", "RequestClass", "SampleDecision", "Sampler",
    "classify", "fleet_status", "golden_key", "log_key", "pow2_bucket",
    "render_fleet_status", "sanitize_id",
]
