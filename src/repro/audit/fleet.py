"""Fleet aggregation: one dashboard over many engines sharing one store.

Every engine flushes its audit log to a reserved ``audit--<engine_id>``
manifest and elects per-class goldens as ``audit-class--<digest>``
manifests (see :mod:`repro.audit.auditor`).  ``fleet_status`` walks a
store — local, ``file://``, or a writable http mirror — and folds those
records into a cross-engine view: per-class energy trend, drift alarms,
sample counts, and each engine's degradation rungs.  This is the data
behind ``python -m repro.cli fleet status --store ...``.
"""

from __future__ import annotations

from typing import Any

from repro.audit.auditor import GOLDEN_PREFIX, LOG_PREFIX
from repro.core.block_cache import is_block_evidence
from repro.core.store import Store, open_store


def _open(store, *, timeout: float | None = None,) -> Store:
    if isinstance(store, Store):
        return store
    return open_store(str(store), timeout=timeout)


def fleet_status(store, *, timeout: float | None = None) -> dict[str, Any]:
    """Aggregate every engine's audit state in ``store`` (URI or Store)."""
    backend = _open(store, timeout=timeout)
    engines: list[dict[str, Any]] = []
    goldens: list[dict[str, Any]] = []
    classes: dict[str, dict[str, Any]] = {}
    n_artifacts = 0
    n_block_entries = 0
    block_cache = {"block_hits": 0, "block_misses": 0,
                   "profile_hits": 0, "profile_misses": 0}

    def cls(key: str) -> dict[str, Any]:
        return classes.setdefault(key, {
            "observed": 0, "sampled": 0, "checks": 0, "alarms": 0,
            "engines": [], "energy_j": None, "energy_deltas": [],
            "diagnosis_kinds": [], "degraded": 0})

    for key in sorted(backend.manifest_keys()):
        if key.startswith(GOLDEN_PREFIX):
            rec = backend.read_manifest(key)
            goldens.append(rec)
            c = cls(rec.get("class_key", "?"))
            c["energy_j"] = rec.get("energy_j")
            continue
        if is_block_evidence(key):
            n_block_entries += 1
            continue
        if not key.startswith(LOG_PREFIX):
            n_artifacts += 1
            continue

        payload = backend.read_manifest(key)
        for k, v in (payload.get("block_cache") or {}).items():
            if k in block_cache:
                block_cache[k] += int(v)
        sampler = payload.get("sampler", {})
        log = payload.get("log", {})
        alarms = payload.get("alarms", [])
        engines.append({
            "engine_id": payload.get("engine_id", key[len(LOG_PREFIX):]),
            "fingerprint": payload.get("fingerprint", ""),
            "observed": sum(sampler.get("counts", {}).values()),
            "sampled": sum(sampler.get("sampled", {}).values()),
            "slo_skipped": sampler.get("slo_skipped", 0),
            "alarms": len(alarms),
            "flush_failures": payload.get("flush_failures", 0),
            "last_error": payload.get("last_error"),
            "degraded_events": sum(1 for ev in log.get("events", ())
                                   if ev.get("degraded")),
        })
        for ck, n in sampler.get("counts", {}).items():
            cls(ck)["observed"] += n
        for ck, n in sampler.get("sampled", {}).items():
            c = cls(ck)
            c["sampled"] += n
            if payload.get("engine_id") not in c["engines"]:
                c["engines"].append(payload.get("engine_id"))
        # the ring keeps recent events in seq order: fold them into the
        # per-class energy trend (deltas vs that class's golden)
        for ev in log.get("events", ()):
            c = cls(ev.get("class_key", "?"))
            if ev.get("kind") in ("check", "alarm"):
                c["checks"] += 1
                if ev.get("energy_delta") is not None:
                    c["energy_deltas"].append(ev["energy_delta"])
            if ev.get("kind") == "alarm":
                c["alarms"] += 1
                if ev.get("diagnosis_kind"):
                    c["diagnosis_kinds"].append(ev["diagnosis_kind"])
            if ev.get("degraded"):
                c["degraded"] += 1

    for c in classes.values():
        deltas = c.pop("energy_deltas")
        c["drift_last"] = deltas[-1] if deltas else None
        c["drift_max"] = max(deltas) if deltas else None
        c["diagnosis_kinds"] = sorted(set(c["diagnosis_kinds"]))
        c["engines"].sort(key=str)
    return {"store": getattr(backend, "uri", str(getattr(backend, "root",
                                                         store))),
            "engines": sorted(engines, key=lambda e: str(e["engine_id"])),
            "classes": {k: classes[k] for k in sorted(classes)},
            "goldens": len(goldens),
            "artifacts": n_artifacts,
            "block_entries": n_block_entries,
            "block_cache": block_cache,
            "total_alarms": sum(e["alarms"] for e in engines)}


def render_fleet_status(status: dict[str, Any]) -> str:
    lines = [f"=== Magneton fleet status: {status['store']} ===",
             f"engines: {len(status['engines'])}   "
             f"request classes: {len(status['classes'])}   "
             f"goldens: {status['goldens']}   "
             f"artifacts: {status['artifacts']}   "
             f"alarms: {status['total_alarms']}"]
    bc = status.get("block_cache") or {}
    n_entries = status.get("block_entries", 0)
    if n_entries or any(bc.values()):
        lines.append(
            f"block evidence: {n_entries} entries   "
            f"block cache: {bc.get('block_hits', 0)} hits / "
            f"{bc.get('block_misses', 0)} misses   "
            f"profile cache: {bc.get('profile_hits', 0)} hits / "
            f"{bc.get('profile_misses', 0)} misses")
    for e in status["engines"]:
        flags = []
        if e["alarms"]:
            flags.append(f"ALARMS={e['alarms']}")
        if e["flush_failures"]:
            flags.append(f"flush_failures={e['flush_failures']}")
        if e["degraded_events"]:
            flags.append(f"degraded={e['degraded_events']}")
        lines.append(f"-- engine {e['engine_id']}: "
                     f"{e['observed']} observed, {e['sampled']} sampled, "
                     f"{e['slo_skipped']} slo-skipped"
                     + (f"   [{' '.join(flags)}]" if flags else ""))
        if e["last_error"]:
            lines.append(f"   last error: {e['last_error']}")
    for key, c in status["classes"].items():
        drift = ("n/a" if c["drift_last"] is None
                 else f"{c['drift_last']:+.2%}")
        energy = ("n/a" if c["energy_j"] is None
                  else f"{c['energy_j']:.3e} J")
        line = (f"   {key}: golden {energy}, drift {drift}, "
                f"{c['sampled']}/{c['observed']} sampled, "
                f"{c['checks']} checks, {c['alarms']} alarms")
        if c["diagnosis_kinds"]:
            line += f"  <- {', '.join(c['diagnosis_kinds'])}"
        if c["degraded"]:
            line += f"  [degraded x{c['degraded']}]"
        lines.append(line)
    return "\n".join(lines)
