"""Bounded audit log: what the engine sampled, found, and flushed.

The :class:`AuditLog` is a ring buffer of :class:`AuditEvent` records —
old events roll off, but per-class *counts* (observations, samples,
alarms) are monotonic and survive the ring, so fleet aggregation never
under-reports a long-running engine just because its buffer wrapped.

The whole log serializes to one JSON payload and is flushed to the
fleet store as a reserved ``audit--<engine_id>`` manifest (see
:mod:`repro.core.store` reserved namespace).  A failed flush keeps every
event in memory for the next attempt — samples are never dropped.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

LOG_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class AuditEvent:
    """One sampled audit: a capture, a drift check, or an alarm."""

    seq: int                         # monotonic per-engine sequence number
    class_key: str                   # RequestClass.key
    reason: str                      # sampler reason ('every_n', ...)
    kind: str                        # 'capture' | 'check' | 'alarm' | 'error'
    latency_s: float | None = None   # engine step latency that triggered it
    energy_delta: float | None = None  # relative energy drift vs golden
    diagnosis_kind: str | None = None  # Diagnosis.kind when kind == 'alarm'
    detail: str = ""
    degraded: bool = False           # capture/compare ran on a degraded rung

    def to_payload(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, payload: dict) -> "AuditEvent":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in payload.items() if k in fields})


class AuditLog:
    """Ring buffer of audit events with monotonic per-class counters."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._events: deque[AuditEvent] = deque(maxlen=self.capacity)
        self._seq = 0
        self.dropped = 0                       # events rolled off the ring
        self.counts: dict[str, dict[str, int]] = {}   # class -> kind -> n

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[AuditEvent]:
        return iter(self._events)

    def record(self, class_key: str, reason: str, kind: str, **kw) -> AuditEvent:
        ev = AuditEvent(seq=self._seq, class_key=class_key, reason=reason,
                        kind=kind, **kw)
        self._seq += 1
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)
        per = self.counts.setdefault(class_key, {})
        per[kind] = per.get(kind, 0) + 1
        return ev

    def alarms(self) -> list[AuditEvent]:
        return [ev for ev in self._events if ev.kind == "alarm"]

    def alarm_count(self) -> int:
        """Total alarms ever recorded (monotonic, survives ring rollover)."""
        return sum(per.get("alarm", 0) for per in self.counts.values())

    def to_payload(self) -> dict:
        return {"schema": LOG_SCHEMA, "capacity": self.capacity,
                "seq": self._seq, "dropped": self.dropped,
                "counts": {k: dict(v) for k, v in self.counts.items()},
                "events": [ev.to_payload() for ev in self._events]}

    @classmethod
    def from_payload(cls, payload: dict) -> "AuditLog":
        log = cls(capacity=int(payload.get("capacity", 256)))
        for ev in payload.get("events", ()):
            log._events.append(AuditEvent.from_payload(ev))
        log._seq = int(payload.get("seq", len(log._events)))
        log.dropped = int(payload.get("dropped", 0))
        log.counts = {k: dict(v)
                      for k, v in payload.get("counts", {}).items()}
        return log
