"""Attention variants: GQA (w/ qk_norm, bias), MLA, and gated cross-attention.

Each variant exposes a schema plus an apply function that covers both the
full-sequence path (train / prefill) and the single-token cached decode path.
GQA keys/values are *broadcast* over query groups via einsum — never
materialized with repeat (that wasteful twin is paper case c4 in the zoo).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, ParamTree, rms_norm, rope
from repro.sharding.rules import constrain

Cache = dict[str, jax.Array]


# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------

def attention_schema(cfg: ModelConfig) -> ParamTree:
    if cfg.use_mla:
        return _mla_schema(cfg)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = cfg.dtype
    sch: ParamTree = {
        "w_q": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "w_k": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "w_v": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "w_o": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), dtype=dt,
                         scale=0.02 / np.sqrt(2.0)),
    }
    if cfg.qkv_bias:
        sch["b_q"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros", dtype=dt)
        sch["b_k"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros", dtype=dt)
        sch["b_v"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros", dtype=dt)
    if cfg.qk_norm:
        sch["q_norm"] = ParamSpec((hd,), (None,), init="ones", dtype="float32")
        sch["k_norm"] = ParamSpec((hd,), (None,), init="ones", dtype="float32")
    return sch


def _mla_schema(cfg: ModelConfig) -> ParamTree:
    d, h = cfg.d_model, cfg.num_heads
    hd = cfg.resolved_head_dim          # qk_nope head dim
    vd = cfg.resolved_v_head_dim
    r = cfg.rope_head_dim
    kvl, ql = cfg.kv_lora_rank, cfg.q_lora_rank
    dt = cfg.dtype
    sch: ParamTree = {
        "w_dkv": ParamSpec((d, kvl), ("embed", None), dtype=dt),
        "kv_norm": ParamSpec((kvl,), (None,), init="ones", dtype="float32"),
        "w_uk": ParamSpec((kvl, h, hd), (None, "heads", "head_dim"), dtype=dt),
        "w_uv": ParamSpec((kvl, h, vd), (None, "heads", "head_dim"), dtype=dt),
        "w_kr": ParamSpec((d, r), ("embed", None), dtype=dt),
        "w_o": ParamSpec((h, vd, d), ("heads", "head_dim", "embed"), dtype=dt,
                         scale=0.02 / np.sqrt(2.0)),
    }
    if ql:
        sch["w_dq"] = ParamSpec((d, ql), ("embed", None), dtype=dt)
        sch["q_norm"] = ParamSpec((ql,), (None,), init="ones", dtype="float32")
        sch["w_uq"] = ParamSpec((ql, h, hd + r), (None, "heads", "head_dim"), dtype=dt)
    else:
        sch["w_q"] = ParamSpec((d, h, hd + r), ("embed", "heads", "head_dim"), dtype=dt)
    return sch


def cross_attention_schema(cfg: ModelConfig) -> ParamTree:
    sch = attention_schema(cfg)
    sch["attn_gate"] = ParamSpec((), (), init="zeros", dtype="float32")
    return sch


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA grouping (broadcast, not repeat)
# ---------------------------------------------------------------------------

def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
          *, scale: float, score_dtype=jnp.float32) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,T,KV,D[v]); returns (B,S,H,Dv).

    score_dtype=bf16 halves the (S,T) matrix's HBM traffic (§Perf lever
    'xla_bf16'): scores and probabilities live at 2 bytes; numerical safety
    comes from the max-subtraction (exp <= 1) plus an f32 softmax
    denominator, so only the per-element probability quantization (~2^-8
    relative) remains — gradients are unaffected at bf16 training precision.
    """
    b, s, h, dq = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dq)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k).astype(score_dtype) * score_dtype(scale)
    if mask is not None:
        scores = jnp.where(mask, scores, score_dtype(-1e30))
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m)
    denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    w = (p / denom.astype(score_dtype)).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, v.shape[-1])


def _causal_mask(s: int, t: int, q_offset: jax.Array | int) -> jax.Array:
    qi = jnp.arange(s)[:, None] + q_offset
    kj = jnp.arange(t)[None, :]
    return (kj <= qi)[None, None, None, :, :]   # (1,1,1,S,T)


def _length_mask(t: int, length: jax.Array) -> jax.Array:
    kj = jnp.arange(t)
    return (kj < length)[None, None, None, None, :]   # (1,1,1,1,T)


def _chunked_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                  causal: bool, q_offset: jax.Array | int = 0,
                  valid_len: jax.Array | None = None,
                  num_chunks: int = 16) -> jax.Array:
    """Online-softmax attention over KV chunks — the flash-attention
    recurrence expressed in XLA (beyond-paper §Perf lever).

    Never materializes the full (S,T) score matrix: each chunk's scores are
    one (B,KV,G,S,T/chunks) tile, and XLA loop-fuses the mask/exp/rescale
    chain into ~2 HBM passes per tile instead of the naive path's ~12 over
    the full matrix.  The chunk loop is Python-unrolled so the dry-run's
    cost_analysis prices every chunk (an inner lax.scan body would be
    counted once).  The Pallas kernel (kernels/flash_attention.py) is the
    TPU-native version of the same recurrence with the tile kept in VMEM.

    q: (B,S,H,D); k/v: (B,T,KV,D).  valid_len masks a partially-filled
    decode cache; q_offset aligns causal positions for cached decode.
    """
    b, s, h, d = q.shape
    t_total, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    nc = num_chunks
    while t_total % nc != 0:
        nc //= 2
    bk = t_total // nc

    m = jnp.full((b, kvh, g, s, 1), -1e30, jnp.float32)
    l = jnp.zeros((b, kvh, g, s, 1), jnp.float32)
    acc = jnp.zeros((b, kvh, g, s, v.shape[-1]), jnp.float32)
    qi = jnp.arange(s)[:, None] + q_offset                    # (S,1)

    for c in range(nc):
        ks = k[:, c * bk:(c + 1) * bk]
        vs = v[:, c * bk:(c + 1) * bk]
        scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                            ks).astype(jnp.float32) * scale
        kj = c * bk + jnp.arange(bk)[None, :]                 # (1,bk)
        mask = None
        if causal:
            mask = kj <= qi
        if valid_len is not None:
            vm = kj < valid_len
            mask = vm if mask is None else jnp.logical_and(mask, vm)
        if mask is not None:
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1, keepdims=True))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new)
        l = l * corr + p.sum(axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bkgst,btkd->bkgsd", p,
                                      vs.astype(jnp.float32))
        m = m_new

    out = acc / jnp.where(l == 0.0, 1.0, l)
    return (out.astype(v.dtype)
            .transpose(0, 3, 1, 2, 4).reshape(b, s, h, v.shape[-1]))


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------

def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dt),
        "v": jnp.zeros((batch, max_len, kv, hd), dt),
    }


def gqa_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
              positions: jax.Array, *, mesh: Mesh | None = None,
              cache: Cache | None = None, cache_pos: jax.Array | None = None,
              causal: bool = True, attn_impl: str = "xla") -> tuple[jax.Array, Cache | None]:
    """x: (B,S,d).  With a cache, S is the new-token count (1 for decode)."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if cfg.qkv_bias:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.is_causal or cfg.family != "audio":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if mesh is not None:
        tp = int(mesh.shape.get("model", 1))
        if cfg.num_heads % tp == 0 or x.shape[1] == 1:
            q = constrain(q, mesh, ("batch", None, "heads", None))
            k = constrain(k, mesh, ("batch", None, "kv_heads", None))
            v = constrain(v, mesh, ("batch", None, "kv_heads", None))
        else:
            # Sequence-parallel attention (§Perf lever): when the head count
            # does not divide the TP axis, head-sharding falls back to full
            # replication — 16x redundant attention compute plus q/k/v
            # all-gathers.  Sharding the *query rows* over the model axis
            # instead keeps the S^2 score tile and its FLOPs 16-way sharded;
            # only the (much smaller) K/V heads are gathered.
            q = constrain(q, mesh, ("batch", "seq_sp", None, None))
            k = constrain(k, mesh, ("batch", None, None, None))
            v = constrain(v, mesh, ("batch", None, None, None))

    scale = 1.0 / float(np.sqrt(hd))
    new_cache = None
    if cache is not None:
        assert cache_pos is not None
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=1)
        new_cache = {"k": k_all, "v": v_all}
        t = k_all.shape[1]
        if attn_impl == "chunked":
            out = _chunked_sdpa(q, k_all, v_all, scale=scale, causal=causal,
                                q_offset=cache_pos,
                                valid_len=cache_pos + x.shape[1])
        else:
            mask = _length_mask(t, cache_pos + x.shape[1])
            if x.shape[1] > 1 and causal:   # chunked prefill into cache
                mask = jnp.logical_and(mask,
                                       _causal_mask(x.shape[1], t, cache_pos))
            out = _sdpa(q, k_all, v_all, mask, scale=scale,
                        score_dtype=(jnp.bfloat16 if attn_impl == "xla_bf16"
                                     else jnp.float32))
    else:
        if attn_impl == "pallas":
            from repro.kernels import ops as kops
            # kernel layout is (B,H,S,D); model layout is (B,S,H,D).
            out = kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=causal,
                sm_scale=scale).transpose(0, 2, 1, 3)
        elif attn_impl == "chunked":
            out = _chunked_sdpa(q, k, v, scale=scale, causal=causal)
        else:
            mask = _causal_mask(x.shape[1], x.shape[1], 0) if causal else None
            out = _sdpa(q, k, v, mask, scale=scale,
                        score_dtype=(jnp.bfloat16 if attn_impl == "xla_bf16"
                                     else jnp.float32))
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA apply (DeepSeek-V2): compressed latent cache + absorbed decode
# ---------------------------------------------------------------------------

def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    dt = jnp.dtype(cfg.dtype)
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dt),
    }


def _mla_q(cfg: ModelConfig, params: ParamTree, x: jax.Array,
           positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    hd, r = cfg.resolved_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
        cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    q_nope, q_rope = q[..., :hd], q[..., hd:hd + r]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
              positions: jax.Array, *, mesh: Mesh | None = None,
              cache: Cache | None = None, cache_pos: jax.Array | None = None,
              causal: bool = True, attn_impl: str = "xla") -> tuple[jax.Array, Cache | None]:
    hd, r = cfg.resolved_head_dim, cfg.rope_head_dim
    scale = 1.0 / float(np.sqrt(hd + r))
    q_nope, q_rope = _mla_q(cfg, params, x, positions)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = rope(jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :],
                  positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        assert cache_pos is not None
        c_all = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, cache_pos, axis=1)
        r_all = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, cache_pos, axis=1)
        new_cache = {"c_kv": c_all, "k_rope": r_all}
        # absorbed decode: project q into the latent space once, attend over
        # the compressed cache, then expand through w_uv. This is the energy
        # win MLA exists for — the cache stays (T, kv_lora + rope) per token.
        # fp32 contraction: the absorbed order reassociates the bf16 matmuls,
        # and per-layer rounding would compound through deep stacks.
        f32 = jnp.float32
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope.astype(f32),
                           params["w_uk"].astype(f32))
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_all.astype(f32))
                  + jnp.einsum("bshk,btk->bhst", q_rope.astype(f32),
                               r_all.astype(f32))) * scale
        t = c_all.shape[1]
        mask = _length_mask(t, cache_pos + x.shape[1])[:, :, 0]   # (1,1,1,T)->(1,1,T)
        if x.shape[1] > 1 and causal:
            mask = jnp.logical_and(
                mask, _causal_mask(x.shape[1], t, cache_pos)[:, :, 0])
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", w, c_all.astype(f32))
        out = jnp.einsum("bshr,rhk->bshk", out_lat,
                         params["w_uv"].astype(f32)).astype(x.dtype)
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      k_nope.shape[:3] + (r,))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        mask = _causal_mask(x.shape[1], x.shape[1], 0) if causal else None
        out = _sdpa(q, k, v, mask, scale=scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    return y, new_cache


# ---------------------------------------------------------------------------
# gated cross-attention (llama-3.2-vision)
# ---------------------------------------------------------------------------

def cross_init_cache(cfg: ModelConfig, batch: int, num_img: int) -> Cache:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k_img": jnp.zeros((batch, num_img, kv, hd), dt),
        "v_img": jnp.zeros((batch, num_img, kv, hd), dt),
    }


def cross_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
                image_embeds: jax.Array | None, *, mesh: Mesh | None = None,
                cache: Cache | None = None,
                attn_impl: str = "xla") -> tuple[jax.Array, Cache | None]:
    """Cross-attend x (B,S,d) to image patch embeddings (B,N,d)."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
    new_cache = None
    if image_embeds is not None:
        k = jnp.einsum("bnd,dhk->bnhk", image_embeds, params["w_k"])
        v = jnp.einsum("bnd,dhk->bnhk", image_embeds, params["w_v"])
        if cfg.qk_norm:
            k = rms_norm(k, params["k_norm"], cfg.norm_eps)
        if cache is not None:
            new_cache = {"k_img": k, "v_img": v}
    else:
        assert cache is not None, "decode needs a prefilled image-KV cache"
        k, v = cache["k_img"], cache["v_img"]
        new_cache = cache
    out = _sdpa(q, k, v, None, scale=1.0 / float(np.sqrt(hd)))
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    gate = jnp.tanh(params["attn_gate"]).astype(y.dtype)
    return y * gate, new_cache
