"""Mixture-of-Experts with expert parallelism.

Two dispatch implementations of the SAME function:

  * ``moe_apply`` (production path): sort/gather-based dispatch — tokens are
    sorted by expert id, scattered into (E, capacity, d) buffers with
    byte-cost O(E*C*d), exchanged across the "model" mesh axis with
    all_to_all (EP), and combined back.  Dispatch costs *bytes*, not FLOPs.

  * ``moe_apply_einsum`` (GShard-style baseline): one-hot dispatch einsums
    costing 2*N*E*C*d FLOPs — for fine-grained-expert models (deepseek-v2:
    160 experts) this is orders of magnitude more compute than the experts
    themselves.  Kept as a first-class energy-waste case for the
    differential debugger (zoo case 'moe-dispatch').

``moe_reference`` is the dropless dense oracle used by tests.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, ParamTree

try:  # JAX >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def moe_schema(cfg: ModelConfig) -> ParamTree:
    d, e, f = cfg.d_model, cfg.moe_num_experts, cfg.resolved_moe_d_ff
    dt = cfg.dtype
    sch: ParamTree = {
        "router": ParamSpec((d, e), ("embed", None), dtype="float32", scale=0.01),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"), dtype=dt),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"), dtype=dt),
        "w_down": ParamSpec((e, f, d), ("experts", "expert_ffn", "embed"), dtype=dt,
                            scale=0.02 / np.sqrt(2.0)),
    }
    if cfg.moe_num_shared:
        s = cfg.moe_num_shared
        sch["shared_w_gate"] = ParamSpec((d, s * f), ("embed", "ffn"), dtype=dt)
        sch["shared_w_up"] = ParamSpec((d, s * f), ("embed", "ffn"), dtype=dt)
        sch["shared_w_down"] = ParamSpec((s * f, d), ("ffn", "embed"), dtype=dt,
                                         scale=0.02 / np.sqrt(2.0))
    return sch


def _capacity(num_tokens: int, k: int, e: int, factor: float) -> int:
    c = int(np.ceil(num_tokens * k / e * factor))
    return max(4, -(-c // 4) * 4)


def _router(cfg: ModelConfig, params: ParamTree, x_flat: jax.Array):
    """top-k routing probabilities. x_flat: (N, d) -> ids (N,k), w (N,k), probs."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, cfg.moe_top_k)
    w = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_ids, w.astype(x_flat.dtype), probs


def _aux_loss(cfg: ModelConfig, probs: jax.Array, top_ids: jax.Array) -> jax.Array:
    """Switch-style load-balancing loss (fp32)."""
    e = cfg.moe_num_experts
    me = jnp.mean(probs, axis=0)                               # (E,)
    counts = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(1.0)
    ce = counts / jnp.maximum(1.0, top_ids.size)
    return e * jnp.sum(me * ce)


def _expert_ffn(buf: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """buf: (E_loc, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _dispatch_local(cfg: ModelConfig, x_flat: jax.Array, top_ids, top_w,
                    capacity: int):
    """Sort-based dispatch. Returns (buffers (E,C,d), slot, tok_idx, keep)."""
    n, d = x_flat.shape
    k, e = cfg.moe_top_k, cfg.moe_num_experts
    flat_e = top_ids.reshape(-1)                       # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_global = jnp.arange(n * k)
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_expert = pos_global - starts[sorted_e]
    keep = pos_in_expert < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos_in_expert, e * capacity)
    tok_idx = order // k
    x_sorted = jnp.take(x_flat, tok_idx, axis=0)       # (N*k, d)
    buf = jnp.zeros((e * capacity, d), x_flat.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x_sorted, 0), mode="drop")
    return buf.reshape(e, capacity, d), slot, tok_idx, order, keep


def _combine_local(cfg: ModelConfig, out_buf: jax.Array, slot, tok_idx, order,
                   keep, top_w, n: int) -> jax.Array:
    e, c, d = out_buf.shape
    flat_out = out_buf.reshape(e * c, d)
    y_sorted = jnp.take(flat_out, jnp.minimum(slot, e * c - 1), axis=0)
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    w_sorted = top_w.reshape(-1)[order][:, None].astype(y_sorted.dtype)
    y = jnp.zeros((n, d), y_sorted.dtype)
    return y.at[tok_idx].add(y_sorted * w_sorted)


def _moe_local(cfg: ModelConfig, params: ParamTree, x: jax.Array,
               *, ep_axis: str | None) -> tuple[jax.Array, jax.Array]:
    """Per-shard MoE body (runs inside shard_map when ep_axis is set)."""
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    n = b * s
    top_ids, top_w, probs = _router(cfg, params, x_flat)
    aux = _aux_loss(cfg, probs, top_ids)
    cap = _capacity(n, cfg.moe_top_k, cfg.moe_num_experts, cfg.capacity_factor)
    buf, slot, tok_idx, order, keep = _dispatch_local(cfg, x_flat, top_ids,
                                                      top_w, cap)
    if ep_axis is not None:
        ep = jax.lax.axis_size(ep_axis)
        # (E, C, d) -> (E/ep, C*ep, d): each shard keeps its local experts'
        # slots from every peer.
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
        out = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
        out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0,
                                 tiled=True)
        del ep
    else:
        out = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
    y = _combine_local(cfg, out, slot, tok_idx, order, keep, top_w, n)
    if cfg.moe_num_shared:
        g = jnp.einsum("nd,df->nf", x_flat, params["shared_w_gate"])
        u = jnp.einsum("nd,df->nf", x_flat, params["shared_w_up"])
        y = y + jnp.einsum("nf,fd->nd", jax.nn.silu(g) * u,
                           params["shared_w_down"])
    return y.reshape(b, s, d), aux


def moe_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
              *, mesh: Mesh | None = None) -> tuple[jax.Array, jax.Array]:
    """Production MoE layer. x: (B,S,d) -> (y, aux_loss)."""
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and mesh.shape["model"] > 1
              and cfg.moe_num_experts % mesh.shape["model"] == 0)
    if not use_ep:
        return _moe_local(cfg, params, x, ep_axis=None)

    # Tokens split across BOTH the data axes (batch dim) and the EP axis
    # (sequence dim): each EP rank dispatches a DISTINCT token slice, and the
    # all_to_all exchanges slices for experts.  Replicating tokens over the
    # EP axis instead (the pre-fix behaviour) made every rank process every
    # token — ep-fold redundant expert FLOPs, flagged by our own
    # differential debugger as redundant compute (EXPERIMENTS.md §Perf B).
    # Divisibility-aware: falls back to replication when a dim can't split
    # (e.g. long_500k decode B=1, S=1).
    from repro.sharding.rules import GLOBAL_RULES
    xs = GLOBAL_RULES.spec(mesh, ("batch", "seq_sp", None), x.shape)
    xs = P(*(tuple(xs) + (None,) * (3 - len(tuple(xs)))))
    ps: dict[str, P] = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    # Routed experts go through shard_map EP; the shared-expert MLP (if any)
    # stays outside under plain GSPMD TP sharding (it is a dense MLP).
    params_routed = {k: v for k, v in params.items() if not k.startswith("shared")}
    cfg_routed = _without_shared(cfg)
    y, aux = shard_map(lambda p, xl: _shardmap_body(cfg_routed, p, xl, mesh),
                       mesh=mesh, in_specs=(ps, xs), out_specs=(xs, P()),
                       check_vma=False)(params_routed, x)
    if cfg.moe_num_shared:
        g = jnp.einsum("bsd,df->bsf", x, params["shared_w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, params["shared_w_up"])
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                           params["shared_w_down"])
    return y, aux


def _without_shared(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, moe_num_shared=0)


def _shardmap_body(cfg: ModelConfig, params_l, x_l, mesh):
    y, aux = _moe_local(cfg, params_l, x_l, ep_axis="model")
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return y, jax.lax.pmean(aux, axes)


# ---------------------------------------------------------------------------
# GShard one-hot dispatch (the wasteful twin — zoo case 'moe-dispatch')
# ---------------------------------------------------------------------------

def moe_apply_einsum(cfg: ModelConfig, params: ParamTree,
                     x: jax.Array) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)
    top_ids, top_w, probs = _router(cfg, params, x_flat)
    aux = _aux_loss(cfg, probs, top_ids)
    e = cfg.moe_num_experts
    cap = _capacity(n, cfg.moe_top_k, e, cfg.capacity_factor)
    # position of each assignment within its expert, via one-hot cumsum
    oh = jax.nn.one_hot(top_ids, e, dtype=jnp.float32)          # (N,k,E)
    pos = jnp.cumsum(oh.reshape(n * cfg.moe_top_k, e), axis=0) - 1.0
    pos = pos.reshape(n, cfg.moe_top_k, e)
    pos = jnp.sum(pos * oh, axis=-1)                            # (N,k)
    keep = pos < cap
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    disp = jnp.einsum("nke,nkc->nec", oh * keep[..., None], cap_oh)
    comb = jnp.einsum("nec,nke->nec", disp,
                      oh * (top_w.astype(jnp.float32))[..., None])
    buf = jnp.einsum("nd,nec->ecd", x_flat.astype(jnp.float32), disp)
    out = _expert_ffn(buf.astype(x.dtype), params["w_gate"], params["w_up"],
                      params["w_down"])
    y = jnp.einsum("ecd,nec->nd", out.astype(jnp.float32), comb).astype(x.dtype)
    if cfg.moe_num_shared:
        g = jnp.einsum("nd,df->nf", x_flat, params["shared_w_gate"])
        u = jnp.einsum("nd,df->nf", x_flat, params["shared_w_up"])
        y = y + jnp.einsum("nf,fd->nd", jax.nn.silu(g) * u,
                           params["shared_w_down"])
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# dropless dense oracle (tests)
# ---------------------------------------------------------------------------

def moe_reference(cfg: ModelConfig, params: ParamTree,
                  x: jax.Array) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)
    top_ids, top_w, probs = _router(cfg, params, x_flat)
    aux = _aux_loss(cfg, probs, top_ids)
    # run every expert on every token, combine by routing weight (no drops)
    g = jnp.einsum("nd,edf->enf", x_flat, params["w_gate"])
    u = jnp.einsum("nd,edf->enf", x_flat, params["w_up"])
    h = jax.nn.silu(g) * u
    full = jnp.einsum("enf,efd->end", h, params["w_down"])       # (E,N,d)
    w_full = jnp.zeros((n, cfg.moe_num_experts), x.dtype)
    w_full = w_full.at[jnp.arange(n)[:, None], top_ids].set(top_w)
    y = jnp.einsum("end,ne->nd", full, w_full)
    if cfg.moe_num_shared:
        gg = jnp.einsum("nd,df->nf", x_flat, params["shared_w_gate"])
        uu = jnp.einsum("nd,df->nf", x_flat, params["shared_w_up"])
        y = y + jnp.einsum("nf,fd->nd", jax.nn.silu(gg) * uu,
                           params["shared_w_down"])
    return y.reshape(b, s, d), aux
