"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunked-parallel)
and sLSTM (scalar memory, sequential recurrence).

mLSTM uses the chunkwise-parallel linear-attention form with log-space
stabilization: within a chunk of Q steps the decay matrix
    D_tj = F_t - F_j + log i_j   (F = cumsum log f,  j <= t)
is materialized (Q×Q per head) and the inter-chunk state (C, n, m) is carried
with jax.lax.scan — the same decomposition as the mlstm_chunk Pallas kernel.
The stored state is de-scaled: true C = C̃ · exp(m).

sLSTM has a genuine nonlinear recurrence (block-diagonal recurrent weights)
and is executed step-by-step with lax.scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, ParamTree, rms_norm
from repro.models.ssm import _causal_conv
from repro.sharding.rules import constrain

Cache = dict[str, jax.Array]

_CONV_W = 4


def _round64(x: float) -> int:
    return max(64, int(round(x / 64)) * 64)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_schema(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)          # inner width
    h = cfg.num_heads
    dh = di // h
    dt = cfg.dtype
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), dtype=dt),
        "conv_w": ParamSpec((_CONV_W, di), (None, "ssm_inner"), dtype=dt, scale=0.1),
        "conv_b": ParamSpec((di,), ("ssm_inner",), init="zeros", dtype=dt),
        "w_q": ParamSpec((h, dh, dh), ("heads", None, None), dtype=dt),
        "w_k": ParamSpec((h, dh, dh), ("heads", None, None), dtype=dt),
        "w_v": ParamSpec((h, dh, dh), ("heads", None, None), dtype=dt),
        "w_if": ParamSpec((di, 2 * h), ("ssm_inner", None), dtype="float32",
                          scale=0.01),
        "b_i": ParamSpec((h,), (None,), init="zeros", dtype="float32"),
        "b_f": ParamSpec((h,), (None,), init="ones", dtype="float32"),
        "out_norm": ParamSpec((di,), ("ssm_inner",), init="ones", dtype="float32"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), dtype=dt,
                              scale=0.02 / np.sqrt(2.0)),
    }


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> Cache:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, di), jnp.dtype(cfg.dtype)),
    }


def _mlstm_chunk(q, k, v, log_i, log_f, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,Q,dh) fp32; log_i/log_f: (B,H,Q); state=(C̃,ñ,m).
    Returns (h (B,H,Q,dh), new_state).
    """
    C, n, m = state
    B, H, Q, dh = q.shape
    F = jnp.cumsum(log_f, axis=-1)                          # (B,H,Q)
    D = (F[..., :, None] - F[..., None, :] + log_i[..., None, :])
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    D = jnp.where(tri, D, -jnp.inf)
    m_intra = jnp.max(D, axis=-1)                           # (B,H,Q)
    m_inter = F + m[..., None]                              # (B,H,Q)
    m_t = jnp.maximum(m_intra, m_inter)
    m_t = jnp.maximum(m_t, -1e30)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k)
    S = scores * jnp.exp(D - m_t[..., None])                # masked via D=-inf
    inter_scale = jnp.exp(m_inter - m_t)                    # (B,H,Q)
    num = (jnp.einsum("bhqk,bhkd->bhqd", S, v)
           + inter_scale[..., None] * jnp.einsum("bhqd,bhde->bhqe", q, C))
    qn = (jnp.sum(S, axis=-1)
          + inter_scale * jnp.einsum("bhqd,bhd->bhq", q, n))
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    h = num / den[..., None]

    # end-of-chunk state
    FQ = F[..., -1:]
    decay_j = FQ - F + log_i                                # (B,H,Q)
    m_new = jnp.maximum(FQ[..., 0] + m, jnp.max(decay_j, axis=-1))
    w_j = jnp.exp(decay_j - m_new[..., None])
    C_new = (jnp.exp(FQ[..., 0] + m - m_new)[..., None, None] * C
             + jnp.einsum("bhq,bhqd,bhqe->bhde", w_j, k, v))
    n_new = (jnp.exp(FQ[..., 0] + m - m_new)[..., None] * n
             + jnp.einsum("bhq,bhqd->bhd", w_j, k))
    return h, (C_new, n_new, m_new)


def mlstm_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
                *, mesh: Mesh | None = None, cache: Cache | None = None,
                decode: bool = False) -> tuple[jax.Array, Cache | None]:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    dh = di // H
    B, S, _ = x.shape

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    if mesh is not None:
        xz = constrain(xz, mesh, ("batch", None, "ssm_inner"))
    xm, z = jnp.split(xz, 2, axis=-1)
    prev_conv = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xm, params["conv_w"], params["conv_b"], prev_conv)
    xc = jax.nn.silu(xc)

    def heads(t, w):
        th = t.reshape(B, S, H, dh).transpose(0, 2, 1, 3).astype(jnp.float32)
        return jnp.einsum("bhsd,hde->bhse", th, w.astype(jnp.float32))

    q = heads(xc, params["w_q"])
    k = heads(xc, params["w_k"]) / np.sqrt(dh)
    v = heads(xm, params["w_v"])
    gates = jnp.einsum("bse,ef->bsf", xc.astype(jnp.float32), params["w_if"])
    gates = gates.reshape(B, S, 2, H).transpose(2, 0, 3, 1)     # (2,B,H,S)
    log_i = gates[0] + params["b_i"][None, :, None]
    log_f = jax.nn.log_sigmoid(gates[1] + params["b_f"][None, :, None])

    if cache is not None:
        state0 = (cache["C"], cache["n"], cache["m"])
    else:
        state0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
                  jnp.zeros((B, H, dh), jnp.float32),
                  jnp.full((B, H), -1e30, jnp.float32))

    if decode:
        assert S == 1
        h, state = _mlstm_chunk(q, k, v, log_i, log_f, state0)
    else:
        Q = min(cfg.ssm_chunk, S)
        assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
        nc = S // Q

        def split_c(t):   # (B,H,S,...) -> (nc,B,H,Q,...)
            return t.reshape(t.shape[0], t.shape[1], nc, Q, *t.shape[3:]) \
                    .transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

        def step(st, inp):
            qc, kc, vc, lic, lfc = inp
            h, st2 = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
            return st2, h

        state, h_chunks = jax.lax.scan(
            step, state0, (split_c(q), split_c(k), split_c(v),
                           split_c(log_i), split_c(log_f)))
        h = h_chunks.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)

    hs = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    # per-head group norm (RMS over each head's slice)
    hs = _group_rms(hs, params["out_norm"], H, cfg.norm_eps)
    y = hs.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"C": state[0], "n": state[1], "m": state[2],
                     "conv": new_conv}
    return out, new_cache


def _group_rms(x: jax.Array, scale: jax.Array, groups: int, eps: float) -> jax.Array:
    """RMS-normalize per head group. x: (B,S,di)."""
    B, S, di = x.shape
    xg = x.reshape(B, S, groups, di // groups).astype(jnp.float32)
    var = jnp.mean(xg * xg, axis=-1, keepdims=True)
    xg = xg * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, S, di) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_schema(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    dt = cfg.dtype
    f = _round64(cfg.slstm_ffn_factor * d)
    return {
        "w_in": ParamSpec((d, 4 * d), ("embed", "ssm_inner"), dtype=dt),
        "r_rec": ParamSpec((4, h, dh, dh), (None, "heads", None, None),
                           dtype="float32", scale=0.02),
        "bias": ParamSpec((4 * d,), (None,), init="zeros", dtype="float32"),
        "out_norm": ParamSpec((d,), ("embed_act",), init="ones", dtype="float32"),
        "ffn_w1": ParamSpec((d, f), ("embed", "ffn"), dtype=dt),
        "ffn_w3": ParamSpec((d, f), ("embed", "ffn"), dtype=dt),
        "ffn_w2": ParamSpec((f, d), ("ffn", "embed"), dtype=dt,
                            scale=0.02 / np.sqrt(2.0)),
    }


def slstm_init_cache(cfg: ModelConfig, batch: int) -> Cache:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(cfg: ModelConfig, params: ParamTree, wx_t: jax.Array,
                state: tuple) -> tuple[jax.Array, tuple]:
    """One recurrence step. wx_t: (B, 4d) input preactivations."""
    c, n, m, h = state
    B, d4 = wx_t.shape
    d = d4 // 4
    H = cfg.num_heads
    dh = d // H
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hh, params["r_rec"]).reshape(4, B, d)
    pre = wx_t.reshape(B, 4, d).transpose(1, 0, 2) + rec + \
        params["bias"].reshape(4, d)[:, None, :]
    zi, ii, fi, oi = pre[0], pre[1], pre[2], pre[3]
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    log_i = ii
    log_f = jax.nn.log_sigmoid(fi)
    m_t = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_t)
    f_s = jnp.exp(log_f + m - m_t)
    c_t = f_s * c + i_s * zt
    n_t = jnp.maximum(f_s * n + i_s, jnp.exp(-m_t))
    h_t = ot * c_t / n_t
    return h_t, (c_t, n_t, m_t, h_t)


def slstm_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
                *, mesh: Mesh | None = None, cache: Cache | None = None,
                decode: bool = False) -> tuple[jax.Array, Cache | None]:
    B, S, d = x.shape
    wx = jnp.einsum("bsd,de->bse", x, params["w_in"]).astype(jnp.float32)
    if cache is not None:
        state0 = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        z = jnp.zeros((B, d), jnp.float32)
        state0 = (z, z, jnp.full((B, d), -1e30, jnp.float32), z)

    if decode:
        assert S == 1
        h_t, state = _slstm_cell(cfg, params, wx[:, 0], state0)
        hs = h_t[:, None]
    else:
        def step(st, wx_t):
            h_t, st2 = _slstm_cell(cfg, params, wx_t, st)
            return st2, h_t
        state, hseq = jax.lax.scan(step, state0, wx.transpose(1, 0, 2))
        hs = hseq.transpose(1, 0, 2)                       # (B,S,d)

    hs = _group_rms(hs, params["out_norm"], cfg.num_heads, cfg.norm_eps)
    y = hs.astype(x.dtype)
    # gated FFN (factor 4/3 per the xLSTM sLSTM block)
    g = jnp.einsum("bsd,df->bsf", y, params["ffn_w1"])
    u = jnp.einsum("bsd,df->bsf", y, params["ffn_w3"])
    out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["ffn_w2"])
    new_cache = None
    if cache is not None:
        new_cache = {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    return out, new_cache
