"""Mamba selective-SSM block (for jamba's hybrid stack).

Training/prefill uses a *chunked* selective scan: within each chunk of
``cfg.ssm_chunk`` steps the first-order linear recurrence
    h_t = a_t * h_{t-1} + b_t,   a_t = exp(dt_t * A),  b_t = dt_t * B_t * x_t
is solved with an associative scan (parallel, MXU/VPU friendly); the state is
carried across chunks with jax.lax.scan.  This is the same decomposition the
``ssm_scan`` Pallas kernel implements on TPU (kernels/ssm_scan.py); the XLA
path here is its oracle.  Decode is the O(1) recurrent step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, ParamTree
from repro.sharding.rules import constrain

Cache = dict[str, jax.Array]


def mamba_schema(cfg: ModelConfig) -> ParamTree:
    d, di = cfg.d_model, cfg.ssm_d_inner
    n, dtr, cw = cfg.ssm_state_dim, cfg.resolved_dt_rank, cfg.ssm_conv_dim
    dt = cfg.dtype
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), dtype=dt),
        "conv_w": ParamSpec((cw, di), (None, "ssm_inner"), dtype=dt, scale=0.1),
        "conv_b": ParamSpec((di,), ("ssm_inner",), init="zeros", dtype=dt),
        "x_proj": ParamSpec((di, dtr + 2 * n), ("ssm_inner", None), dtype=dt),
        "dt_proj": ParamSpec((dtr, di), (None, "ssm_inner"), dtype=dt, scale=0.1),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), init="ssm_dt_bias", dtype="float32"),
        "a_log": ParamSpec((di, n), ("ssm_inner", "ssm_state"), init="ssm_a_log",
                           dtype="float32"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), init="ones", dtype="float32"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), dtype=dt,
                              scale=0.02 / np.sqrt(2.0)),
    }


def mamba_init_cache(cfg: ModelConfig, batch: int) -> Cache:
    di, n, cw = cfg.ssm_d_inner, cfg.ssm_state_dim, cfg.ssm_conv_dim
    return {
        "conv": jnp.zeros((batch, cw - 1, di), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via shifted adds. x: (B,S,di), w: (cw,di)."""
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)           # (B, S+cw-1, di)
    s = x.shape[1]
    out = b
    for i in range(cw):
        out = out + xp[:, i:i + s, :] * w[i]
    new_prev = xp[:, -(cw - 1):, :] if cw > 1 else prev
    return out, new_prev


def _chunked_selective_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                            chunk: int) -> tuple[jax.Array, jax.Array]:
    """Solve h_t = a_t h_{t-1} + b_t.  a,b: (B,S,di,n); h0: (B,di,n).

    Returns (h per step (B,S,di,n), final h).  Chunked associative scan:
    O(S/Q) sequential steps of parallel O(Q) scans.
    """
    B, S, di, n = a.shape
    q = min(chunk, S)
    assert S % q == 0, f"seq {S} not divisible by ssm chunk {q}"
    nc = S // q
    a_c = a.reshape(B, nc, q, di, n).transpose(1, 0, 2, 3, 4)
    b_c = b.reshape(B, nc, q, di, n).transpose(1, 0, 2, 3, 4)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, ab):
        ac, bc = ab                                # (B,q,di,n)
        aa, bb = jax.lax.associative_scan(op, (ac, bc), axis=1)
        h_steps = aa * h[:, None] + bb             # (B,q,di,n)
        return h_steps[:, -1], h_steps

    h_last, h_all = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_all = h_all.transpose(1, 0, 2, 3, 4).reshape(B, S, di, n)
    return h_all, h_last


def mamba_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
                *, mesh: Mesh | None = None, cache: Cache | None = None,
                decode: bool = False) -> tuple[jax.Array, Cache | None]:
    """x: (B,S,d). decode=True runs the O(1) recurrent step (S==1)."""
    di, n, dtr = cfg.ssm_d_inner, cfg.ssm_state_dim, cfg.resolved_dt_rank
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    if mesh is not None:
        xz = constrain(xz, mesh, ("batch", None, "ssm_inner"))
    xin, z = jnp.split(xz, 2, axis=-1)

    prev_conv = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"], prev_conv)
    xc = jax.nn.silu(xc)

    proj = jnp.einsum("bse,ef->bsf", xc, params["x_proj"])
    dt_raw, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_raw, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                                     # (B,S,di)
    a = -jnp.exp(params["a_log"])                                # (di,n)
    da = jnp.exp(dt[..., None] * a)                              # (B,S,di,n)
    bx = (dt * xc.astype(jnp.float32))[..., None] * \
        bmat.astype(jnp.float32)[:, :, None, :]                  # (B,S,di,n)

    if decode:
        assert cache is not None and x.shape[1] == 1
        h = cache["h"] * da[:, 0] + bx[:, 0]                     # (B,di,n)
        y = jnp.einsum("ben,bn->be", h, cmat.astype(jnp.float32)[:, 0])[:, None]
        h_last = h
    else:
        h0 = cache["h"] if cache is not None else \
            jnp.zeros((x.shape[0], di, n), jnp.float32)
        h_all, h_last = _chunked_selective_scan(da, bx, h0, cfg.ssm_chunk)
        y = jnp.einsum("bsen,bsn->bse", h_all, cmat.astype(jnp.float32))

    y = y + params["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_last}
    return out, new_cache
