"""Model assembly: superblock schemas, scan-stacked forward, prefill/decode.

The layer stack is ``cfg.num_superblocks`` repetitions of the
``cfg.block_pattern`` superblock, scanned with jax.lax.scan (HLO size O(1) in
depth) and rematerialized per superblock.  Caches/params are pytrees whose
leaves carry a leading stack dimension.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (ParamSpec, ParamTree, abstract_params,
                                 cross_entropy, embed_tokens, embedding_schema,
                                 init_params, lm_head, mlp_apply, mlp_schema,
                                 param_shardings, rms_norm, stack_schema)
from repro.sharding.rules import constrain

# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------

def layer_schema(cfg: ModelConfig, spec: LayerSpec) -> ParamTree:
    d, dt = cfg.d_model, cfg.dtype
    sch: ParamTree = {
        "norm1": ParamSpec((d,), ("embed_act",), init="ones", dtype="float32"),
    }
    if spec.mixer == "attn":
        sch["attn"] = attn_mod.attention_schema(cfg)
    elif spec.mixer == "cross_attn":
        sch["attn"] = attn_mod.cross_attention_schema(cfg)
    elif spec.mixer == "mamba":
        sch["mamba"] = ssm_mod.mamba_schema(cfg)
    elif spec.mixer == "mlstm":
        sch["mlstm"] = xlstm_mod.mlstm_schema(cfg)
    elif spec.mixer == "slstm":
        sch["slstm"] = xlstm_mod.slstm_schema(cfg)
    else:
        raise ValueError(f"unknown mixer {spec.mixer}")
    if spec.ffn == "dense":
        sch["norm2"] = ParamSpec((d,), ("embed_act",), init="ones", dtype="float32")
        sch["mlp"] = mlp_schema(d, cfg.d_ff, dt)
    elif spec.ffn == "moe":
        sch["norm2"] = ParamSpec((d,), ("embed_act",), init="ones", dtype="float32")
        sch["moe"] = moe_mod.moe_schema(cfg)
    return sch


def superblock_schema(cfg: ModelConfig) -> ParamTree:
    return {f"layer{i}": layer_schema(cfg, spec)
            for i, spec in enumerate(cfg.block_pattern)}


def model_schema(cfg: ModelConfig) -> ParamTree:
    sch: ParamTree = {
        "embed": embedding_schema(cfg.vocab_size, cfg.d_model, cfg.dtype,
                                  cfg.tie_embeddings),
        "blocks": stack_schema(superblock_schema(cfg), cfg.num_superblocks),
        "final_norm": ParamSpec((cfg.d_model,), ("embed_act",), init="ones",
                                dtype="float32"),
    }
    return sch


def model_abstract_params(cfg: ModelConfig) -> ParamTree:
    return abstract_params(model_schema(cfg))


def model_param_shardings(cfg: ModelConfig, mesh: Mesh) -> ParamTree:
    return param_shardings(model_schema(cfg), mesh)


def model_init(cfg: ModelConfig, key) -> ParamTree:
    return init_params(model_schema(cfg), key)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    from repro.models.layers import count_schema_params
    total = count_schema_params(model_schema(cfg))
    if active_only and cfg.moe_num_experts:
        e, k = cfg.moe_num_experts, cfg.moe_top_k
        routed = 0
        for spec in cfg.block_pattern:
            if spec.ffn == "moe":
                routed += 3 * cfg.d_model * cfg.resolved_moe_d_ff * e
        routed *= cfg.num_superblocks
        total -= int(routed * (1.0 - k / e))
    return total


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                max_len: int) -> dict | None:
    if spec.mixer == "attn":
        if cfg.use_mla:
            return attn_mod.mla_init_cache(cfg, batch, max_len)
        return attn_mod.gqa_init_cache(cfg, batch, max_len)
    if spec.mixer == "cross_attn":
        return attn_mod.cross_init_cache(cfg, batch, cfg.num_image_tokens)
    if spec.mixer == "mamba":
        return ssm_mod.mamba_init_cache(cfg, batch)
    if spec.mixer == "mlstm":
        return xlstm_mod.mlstm_init_cache(cfg, batch)
    if spec.mixer == "slstm":
        return xlstm_mod.slstm_init_cache(cfg, batch)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> ParamTree:
    """Decode cache pytree with a leading (num_superblocks,) stack dim."""
    one = {f"layer{i}": layer_cache(cfg, spec, batch, max_len)
           for i, spec in enumerate(cfg.block_pattern)}
    nsb = cfg.num_superblocks
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (nsb,) + a.shape).copy(), one)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> ParamTree:
    one = {f"layer{i}": layer_cache(cfg, spec, batch, max_len)
           for i, spec in enumerate(cfg.block_pattern)}
    nsb = cfg.num_superblocks
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct((nsb,) + a.shape, a.dtype), one)


def cache_logical_axes(cfg: ModelConfig) -> ParamTree:
    """Logical sharding axes for every cache leaf (for in/out_shardings)."""
    def axes_for(name: str, leaf_shape_len: int, mixer: str):
        if mixer in ("attn",):
            if cfg.use_mla:
                return ("stack", "batch", "kv_seq", None)
            return ("stack", "batch", "kv_seq", "kv_heads", None)
        if mixer == "cross_attn":
            return ("stack", "batch", None, "kv_heads", None)
        if mixer == "mamba":
            return {"conv": ("stack", "batch", None, "ssm_inner"),
                    "h": ("stack", "batch", "ssm_inner", None)}[name]
        if mixer == "mlstm":
            return {"C": ("stack", "batch", "heads", None, None),
                    "n": ("stack", "batch", "heads", None),
                    "m": ("stack", "batch", "heads"),
                    "conv": ("stack", "batch", None, "ssm_inner")}[name]
        if mixer == "slstm":
            return ("stack", "batch", None)
        raise ValueError(mixer)

    out: dict = {}
    for i, spec in enumerate(cfg.block_pattern):
        lc = layer_cache(cfg, spec, 1, 8)
        out[f"layer{i}"] = {k: axes_for(k, v.ndim + 1, spec.mixer)
                            for k, v in lc.items()}
    return out


# ---------------------------------------------------------------------------
# layer / superblock application
# ---------------------------------------------------------------------------

def layer_apply(cfg: ModelConfig, spec: LayerSpec, params: ParamTree,
                x: jax.Array, positions: jax.Array, *,
                mesh: Mesh | None = None, cache: dict | None = None,
                cache_pos=None, image_embeds: jax.Array | None = None,
                decode: bool = False, attn_impl: str = "xla"):
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_cache = None
    if spec.mixer == "attn":
        apply = attn_mod.mla_apply if cfg.use_mla else attn_mod.gqa_apply
        out, new_cache = apply(cfg, params["attn"], h, positions, mesh=mesh,
                               cache=cache, cache_pos=cache_pos,
                               causal=cfg.is_causal, attn_impl=attn_impl)
    elif spec.mixer == "cross_attn":
        out, new_cache = attn_mod.cross_apply(cfg, params["attn"], h,
                                              image_embeds, mesh=mesh,
                                              cache=cache, attn_impl=attn_impl)
    elif spec.mixer == "mamba":
        out, new_cache = ssm_mod.mamba_apply(cfg, params["mamba"], h, mesh=mesh,
                                             cache=cache, decode=decode)
    elif spec.mixer == "mlstm":
        out, new_cache = xlstm_mod.mlstm_apply(cfg, params["mlstm"], h,
                                               mesh=mesh, cache=cache,
                                               decode=decode)
    elif spec.mixer == "slstm":
        out, new_cache = xlstm_mod.slstm_apply(cfg, params["slstm"], h,
                                               mesh=mesh, cache=cache,
                                               decode=decode)
    else:
        raise ValueError(spec.mixer)
    x = x + out
    if mesh is not None:
        x = constrain(x, mesh, ("batch", "seq_sp", None))

    if spec.ffn != "none":
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if spec.ffn == "dense":
            out2 = mlp_apply(params["mlp"], h2, mesh=mesh)
        else:
            out2, aux = moe_mod.moe_apply(cfg, params["moe"], h2, mesh=mesh)
        x = x + out2
        if mesh is not None:
            x = constrain(x, mesh, ("batch", "seq_sp", None))
    return x, new_cache, aux


def superblock_apply(cfg: ModelConfig, params: ParamTree, x: jax.Array,
                     positions: jax.Array, *, mesh=None, cache=None,
                     cache_pos=None, image_embeds=None, decode=False,
                     attn_impl="xla"):
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    for i, spec in enumerate(cfg.block_pattern):
        lc = cache[f"layer{i}"] if cache is not None else None
        x, nc, aux = layer_apply(cfg, spec, params[f"layer{i}"], x, positions,
                                 mesh=mesh, cache=lc, cache_pos=cache_pos,
                                 image_embeds=image_embeds, decode=decode,
                                 attn_impl=attn_impl)
        aux_total = aux_total + aux
        if nc is not None:
            new_cache[f"layer{i}"] = nc
    return x, (new_cache or None), aux_total


def stack_apply(cfg: ModelConfig, blocks: ParamTree, x: jax.Array,
                positions: jax.Array, *, mesh=None, caches=None,
                cache_pos=None, image_embeds=None, decode=False,
                remat: bool | str = True, attn_impl: str = "xla"):
    """Scan the superblock over the stacked params/caches.

    remat: False | True ("full": recompute everything in bwd) | "dots"
    (save matmul outputs: no fwd recompute of dots in bwd, so parameter
    all-gathers and the S^2 attention scores are not re-paid — §Perf lever;
    costs peak activation memory).
    """
    use_cache = caches is not None

    def body(carry, inp):
        xc = carry
        if use_cache:
            p_i, c_i = inp
        else:
            p_i, c_i = inp, None
        out, nc, aux = superblock_apply(cfg, p_i, xc, positions, mesh=mesh,
                                        cache=c_i, cache_pos=cache_pos,
                                        image_embeds=image_embeds,
                                        decode=decode, attn_impl=attn_impl)
        return out, (nc, aux) if use_cache else aux

    if remat == "dots":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.dots_saveable)
    elif remat:
        body = jax.checkpoint(body)

    xs = (blocks, caches) if use_cache else blocks
    x, ys = jax.lax.scan(body, x, xs)
    if use_cache:
        new_caches, auxs = ys
    else:
        new_caches, auxs = None, ys
    return x, new_caches, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# public model functions
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: ParamTree, tokens: jax.Array | None,
            *, mesh: Mesh | None = None, inputs_embeds: jax.Array | None = None,
            image_embeds: jax.Array | None = None, remat: bool = True,
            attn_impl: str = "xla", logits_mode: str = "all"):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params["embed"], tokens)
    if mesh is not None:
        x = constrain(x, mesh, ("batch", "seq_sp", None))
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
    x, _, aux = stack_apply(cfg, params["blocks"], x, positions, mesh=mesh,
                            image_embeds=image_embeds, remat=remat,
                            attn_impl=attn_impl)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "last":
        x = x[:, -1:, :]
    logits = lm_head(params["embed"], x)
    if mesh is not None:
        logits = constrain(logits, mesh, ("batch", None, "vocab"))
    return logits, aux


def prefill(cfg: ModelConfig, params: ParamTree, tokens: jax.Array, *,
            mesh=None, max_len: int, image_embeds=None, remat: bool = True,
            attn_impl: str = "xla"):
    """Process the prompt, build the decode cache, return last-token logits.

    Only the final position's logits are computed (the efficient LMHead
    path; computing all-position logits during prefill is zoo case
    'lmhead-redundant' / hf-38977).
    """
    x = embed_tokens(params["embed"], tokens)
    if mesh is not None:
        x = constrain(x, mesh, ("batch", "seq_sp", None))
    s = x.shape[1]
    caches = init_cache(cfg, tokens.shape[0], max_len)
    positions = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
    x, new_caches, aux = stack_apply(cfg, params["blocks"], x, positions,
                                     mesh=mesh, caches=caches,
                                     cache_pos=jnp.int32(0),
                                     image_embeds=image_embeds, remat=remat,
                                     attn_impl=attn_impl)
    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = lm_head(params["embed"], x)
    return logits, new_caches


def decode_step(cfg: ModelConfig, params: ParamTree, caches: ParamTree,
                tokens: jax.Array, pos, *, mesh=None,
                attn_impl: str = "xla"):
    """One decode step. tokens: (B,1); pos: scalar current length (or (B,))."""
    x = embed_tokens(params["embed"], tokens)
    if jnp.ndim(pos) == 0:
        pos_bc = pos[None, None]
    elif jnp.ndim(pos) == 1:
        pos_bc = pos[:, None]
    else:
        pos_bc = pos
    positions = jnp.broadcast_to(pos_bc, tokens.shape)
    x, new_caches, _ = stack_apply(cfg, params["blocks"], x, positions,
                                   mesh=mesh, caches=caches, cache_pos=pos,
                                   decode=True, remat=False,
                                   attn_impl=attn_impl)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params["embed"], x)
    return logits, new_caches
