"""Shared model substrate: param schemas, norms, RoPE, MLPs, embeddings.

Parameters are described by a *schema* (nested dict of ParamSpec) before they
are materialized.  The schema carries logical sharding axes, so the same
definition serves three consumers:
  * init_params      — materialize real arrays (smoke tests, training),
  * abstract_params  — ShapeDtypeStructs (the multi-pod dry-run; no allocation),
  * param_shardings  — NamedShardings for pjit in/out_shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.sharding.rules import GLOBAL_RULES, ShardingRules, constrain


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[Any, ...]
    init: str = "normal"          # normal | zeros | ones | ssm_a_log | ssm_dt_bias
    scale: float = 0.02
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


ParamTree = dict  # nested dict of ParamSpec / arrays


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "ssm_a_log":
        # S4/Mamba init: A = -(1..d_state) broadcast; store log(-A)
        d_state = spec.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                             spec.shape)
        return jnp.log(a).astype(dt)
    if spec.init == "ssm_dt_bias":
        # softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               minval=np.log(1e-3), maxval=np.log(1e-1))
        dt = jnp.exp(u)
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(spec.dtype)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale
            ).astype(dt)


def _is_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(schema: ParamTree, key) -> ParamTree:
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(schema: ParamTree) -> ParamTree:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        schema, is_leaf=_is_leaf)


def param_shardings(schema: ParamTree, mesh: Mesh,
                    rules: ShardingRules = GLOBAL_RULES) -> ParamTree:
    return jax.tree_util.tree_map(
        lambda s: rules.sharding(mesh, s.logical, s.shape),
        schema, is_leaf=_is_leaf)


def stack_schema(schema: ParamTree, n: int) -> ParamTree:
    """Prepend a scan-stack dimension to every leaf in the schema."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec(shape=(n,) + s.shape, logical=("stack",) + s.logical,
                            init=s.init, scale=s.scale, dtype=s.dtype),
        schema, is_leaf=_is_leaf)


def count_schema_params(schema: ParamTree) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=_is_leaf)
    return int(sum(np.prod(s.shape, dtype=np.int64) for s in leaves))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 internals (the production-default path)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (..., seq, heads, head_dim), positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_schema(d_model: int, d_ff: int, dtype: str) -> ParamTree:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype=dtype),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype=dtype),
        "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed"), dtype=dtype,
                            scale=0.02 / np.sqrt(2.0)),
    }


def mlp_apply(params: ParamTree, x: jax.Array, *, mesh: Mesh | None = None,
              fused_activation: Callable | None = None) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if mesh is not None:
        gate = constrain(gate, mesh, ("batch", None, "ffn"))
        up = constrain(up, mesh, ("batch", None, "ffn"))
    act = (fused_activation or _swiglu)(gate, up)
    return jnp.einsum("...f,fd->...d", act, params["w_down"])


def _swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embedding_schema(vocab: int, d_model: int, dtype: str,
                     tie: bool) -> ParamTree:
    sch: ParamTree = {
        "tok_embed": ParamSpec((vocab, d_model), ("vocab", "embed"), dtype=dtype),
    }
    if not tie:
        sch["lm_head"] = ParamSpec((d_model, vocab), ("embed", "vocab"),
                                   dtype=dtype)
    return sch


def embed_tokens(params: ParamTree, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok_embed"], tokens, axis=0)


def lm_head(params: ParamTree, x: jax.Array) -> jax.Array:
    if "lm_head" in params:
        return jnp.einsum("...d,dv->...v", x, params["lm_head"])
    return jnp.einsum("...d,vd->...v", x, params["tok_embed"])


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  *, z_loss: float = 0.0) -> jax.Array:
    """Efficient CE: log_softmax + take_along_axis (no one-hot materialized).

    The wasteful twin (one-hot einsum over the full vocab) lives in
    zoo/cases.py as paper case c13.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1, keepdims=True)
    logp = lf - lse
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse**2)
    return loss
