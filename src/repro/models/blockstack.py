"""Weight-tied heterogeneous transformer-block stack.

A two-stage model for exercising MULTI-FAMILY block machinery on a
non-homogeneous graph (the PR 9 headroom item): ``attn_layers`` repeats of
a full transformer block (RMSNorm -> multi-head self-attention -> residual
-> RMSNorm -> SwiGLU MLP -> residual) followed by ``mlp_layers`` repeats
of a lighter norm+MLP block.  ``block_structure`` finds two distinct
repeated-block families, so the fused capture, the block stamper, and the
block-evidence cache (core/block_cache.py) all run with heterogeneous
family digests in one graph.

Weights are TIED across layers (ALBERT-style parameter sharing).  This is
load-bearing, not a shortcut: struct digests embed const VALUE digests, so
per-layer weights would make every layer structurally unique and no family
would form.  Tied weights match how block families arise in practice —
identical program text per layer — while the *activations* still differ
per layer (each block's inputs are the previous block's outputs), which is
exactly what the block cache keys on.
"""

from __future__ import annotations

import numpy as np

from repro.models.layers import mlp_apply, rms_norm


def transformer_block_stack(attn_layers: int = 6, mlp_layers: int = 6, *,
                            d_model: int = 64, n_heads: int = 4,
                            d_ff: int | None = None, seq: int = 16,
                            batch: int = 2, dtype: str = "float32",
                            seed: int = 0):
    """Build ``(fn, example_args)`` for a tied-weight two-family stack.

    ``fn(x)`` closes over the shared weights; ``example_args`` is a single
    ``(batch, seq, d_model)`` activation tensor.
    """
    import jax
    import jax.numpy as jnp

    if d_ff is None:
        d_ff = 2 * d_model
    if d_model % n_heads:
        raise ValueError(f"d_model={d_model} not divisible by n_heads={n_heads}")
    head_dim = d_model // n_heads

    rng = np.random.default_rng(seed)

    def mat(shape, scale):
        return jnp.asarray(
            rng.standard_normal(shape).astype(dtype) * np.asarray(
                scale, dtype=dtype))

    wq = mat((d_model, d_model), 1.0 / np.sqrt(d_model))
    wk = mat((d_model, d_model), 1.0 / np.sqrt(d_model))
    wv = mat((d_model, d_model), 1.0 / np.sqrt(d_model))
    wo = mat((d_model, d_model), 1.0 / np.sqrt(d_model))
    g_attn = jnp.asarray(1.0 + 0.1 * rng.standard_normal(d_model)
                         .astype(dtype))
    g_mlp = jnp.asarray(1.0 + 0.1 * rng.standard_normal(d_model)
                        .astype(dtype))
    g_tail = jnp.asarray(1.0 + 0.1 * rng.standard_normal(d_model)
                         .astype(dtype))
    mlp_params = {"w_gate": mat((d_model, d_ff), 0.5 / np.sqrt(d_model)),
                  "w_up": mat((d_model, d_ff), 0.5 / np.sqrt(d_model)),
                  "w_down": mat((d_ff, d_model), 0.5 / np.sqrt(d_ff))}
    tail_params = {"w_gate": mlp_params["w_gate"],
                   "w_up": mlp_params["w_up"],
                   "w_down": mlp_params["w_down"]}

    def attention(h):
        b, s, _ = h.shape
        q = (h @ wq).reshape(b, s, n_heads, head_dim)
        k = (h @ wk).reshape(b, s, n_heads, head_dim)
        v = (h @ wv).reshape(b, s, n_heads, head_dim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(head_dim)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return ctx.reshape(b, s, d_model) @ wo

    def attn_block(x):
        x = x + attention(rms_norm(x, g_attn))
        return x + mlp_apply(mlp_params, rms_norm(x, g_mlp))

    def mlp_block(x):
        return x + mlp_apply(tail_params, rms_norm(x, g_tail))

    def fn(x):
        for _ in range(attn_layers):
            x = attn_block(x)
        for _ in range(mlp_layers):
            x = mlp_block(x)
        return x

    x0 = jnp.asarray(rng.standard_normal((batch, seq, d_model))
                     .astype(dtype))
    return fn, (x0,)
