"""Serving steps: batched prefill and single-token decode.

``serve_step`` (decode) computes logits for the new position ONLY — computing
all-position logits with a 32k cache is zoo case 'lmhead-redundant'
(hf-38977).  Both steps are pure functions of (params, cache, tokens, pos)
so they jit/shard cleanly on the production mesh.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


def make_prefill_step(cfg: ModelConfig, mesh: Mesh | None, *, max_len: int,
                      attn_impl: str = "xla") -> Callable:
    def prefill_step(params, tokens, image_embeds=None, frames=None):
        if cfg.family == "audio":
            # encoder: no cache; "prefill" is the full encoder forward
            logits, _ = tf.forward(cfg, params, None, inputs_embeds=frames,
                                   mesh=mesh, remat=True, attn_impl=attn_impl)
            return logits, None
        logits, caches = tf.prefill(cfg, params, tokens, mesh=mesh,
                                    max_len=max_len,
                                    image_embeds=image_embeds,
                                    attn_impl=attn_impl)
        return logits, caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh | None,
                     attn_impl: str = "xla") -> Callable:
    def decode_step(params, caches, tokens, pos):
        logits, new_caches = tf.decode_step(cfg, params, caches, tokens, pos,
                                            mesh=mesh, attn_impl=attn_impl)
        return logits, new_caches
    return decode_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
