"""Batched serving engine: continuous batching over prefill/decode steps.

A minimal but real engine: requests enter a queue, are prefilled in batches,
then decoded together with a shared step counter.  Slot management keeps the
decode batch full (continuous batching); finished sequences free their slot
for the next queued request.  The engine exposes an optional Magneton energy
audit per phase (``energy_report()``) — the paper's profiler as a deployment
feature.

The audit path sits behind an error boundary (:meth:`ServeEngine.audit`):
a watchdog thread bounds how long an audit may run, every failure is
absorbed into ``stats`` counters, and a circuit breaker disables further
audits after ``audit_breaker_threshold`` consecutive failures — a broken
profiler must never take the serving path down with it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.audit import AuditConfig, EngineAuditor, RequestClass
from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve.serve_step import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 4             # decode slots
    max_len: int = 256
    eos_id: int = -1                # -1: never stop early
    attn_impl: str = "xla"
    # audit error boundary (docs/robustness.md): wall-clock budget for one
    # energy audit, and how many consecutive failures open the breaker
    audit_timeout_s: float = 120.0
    audit_breaker_threshold: int = 3
    # always-on sampled auditing (docs/serving.md).  A sampler trigger must
    # be set (cadence and/or SLO headroom) for live audits to run; the
    # store URI makes captures/goldens/logs land in a shared fleet store.
    audit_sample_every: int = 0      # every-Nth cadence per class (0 = off)
    audit_slo_ms: float | None = None
    audit_slo_headroom: float = 0.5
    store: str | None = None         # fleet store URI (file:// or http(s)://)
    engine_id: str | None = None     # None: derived from arch + pid
    audit_seed: int = 0
    audit_recheck_every: int = 0     # full drift re-check cadence (0 = once)
    audit_energy_rtol: float = 0.05
    # demo/chaos hook: audit the decode probe through a waste mutation
    # (repro.testing.mutate name) — simulates a regressed engine that must
    # alarm against the healthy fleet golden
    audit_mutate_decode: str | None = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, mesh: Mesh | None = None,
                 ecfg: EngineConfig | None = None):
        assert cfg.is_causal, "encoder-only models have no decode path"
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        # None default: a shared `ecfg=EngineConfig()` dataclass default
        # would alias one mutable config across every engine construction
        self.ecfg = ecfg if ecfg is not None else EngineConfig()
        # raw (traceable) prefill kept alongside the jitted one: the live
        # audit probe captures through it so Magneton sees real operators
        self._prefill_fn = make_prefill_step(
            cfg, mesh, max_len=self.ecfg.max_len,
            attn_impl=self.ecfg.attn_impl)
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(make_decode_step(cfg, mesh,
                                                attn_impl=self.ecfg.attn_impl))
        self.stats = {"prefill_calls": 0, "decode_calls": 0,
                      "tokens_generated": 0, "prefill_s": 0.0, "decode_s": 0.0,
                      # audit-health counters (the audit error boundary)
                      "audit_calls": 0, "audit_ok": 0, "audit_failures": 0,
                      "audit_timeouts": 0, "audit_skipped": 0,
                      "audit_degraded": 0, "audit_consecutive_failures": 0,
                      "audit_breaker_open": False,
                      # live sampled auditing (repro.audit)
                      "audit_sampled": 0, "audit_alarms": 0}
        ecfg = self.ecfg
        self.engine_id = ecfg.engine_id or f"{cfg.name}-{os.getpid()}"
        self.auditor: EngineAuditor | None = None
        if (ecfg.audit_sample_every > 0 or ecfg.audit_slo_ms is not None
                or ecfg.store is not None):
            self.auditor = EngineAuditor(
                self._audit_probe, self._audit_fingerprint(),
                AuditConfig(engine_id=self.engine_id, store=ecfg.store,
                            sample_every=ecfg.audit_sample_every,
                            slo_ms=ecfg.audit_slo_ms,
                            slo_headroom=ecfg.audit_slo_headroom,
                            seed=ecfg.audit_seed,
                            energy_rtol=ecfg.audit_energy_rtol,
                            recheck_every=ecfg.audit_recheck_every))

    # -- batch serving --------------------------------------------------------
    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests with continuous batching."""
        ecfg = self.ecfg
        queue = list(requests)
        B = min(ecfg.batch_size, len(queue))
        if B == 0:
            return requests

        # pad all prompts in one prefill batch per wave
        waves = [queue[i:i + B] for i in range(0, len(queue), B)]
        for wave in waves:
            self._serve_wave(wave)
        if self.auditor is not None:
            self.auditor.flush()        # deliver batched capture events
        return requests

    def _serve_wave(self, wave: list[Request]):
        ecfg = self.ecfg
        B = len(wave)
        plen = max(len(r.prompt) for r in wave)
        tokens = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            tokens[i, plen - len(r.prompt):] = r.prompt    # left-pad
        t0 = time.time()
        img = None
        if self.cfg.family == "vlm":
            img = jnp.zeros((B, self.cfg.num_image_tokens, self.cfg.d_model),
                            jnp.dtype(self.cfg.dtype))
        logits, caches = self._prefill(self.params, jnp.asarray(tokens), img)
        dt = time.time() - t0
        self.stats["prefill_calls"] += 1
        self.stats["prefill_s"] += dt
        self._observe_audit("prefill", B, plen, latency_s=dt)

        next_tok = np.asarray(jnp.argmax(logits[:, -1, :], -1),
                              np.int32)[:, None]
        for i, r in enumerate(wave):
            r.generated.append(int(next_tok[i, 0]))

        pos = plen
        max_new = max(r.max_new_tokens for r in wave)
        for _ in range(max_new - 1):
            if pos >= ecfg.max_len:
                break
            t0 = time.time()
            logits, caches = self._decode(self.params, caches,
                                          jnp.asarray(next_tok),
                                          jnp.int32(pos))
            dt = time.time() - t0
            self.stats["decode_calls"] += 1
            self.stats["decode_s"] += dt
            self._observe_audit("decode", B, pos, latency_s=dt)
            next_tok = np.asarray(jnp.argmax(logits[:, -1, :], -1),
                                  np.int32)[:, None]
            pos += 1
            for i, r in enumerate(wave):
                if r.done or len(r.generated) >= r.max_new_tokens:
                    r.done = True
                    continue
                t = int(next_tok[i, 0])
                r.generated.append(t)
                if t == ecfg.eos_id:
                    r.done = True
            if all(r.done or len(r.generated) >= r.max_new_tokens
                   for r in wave):
                break
        self.stats["tokens_generated"] += sum(len(r.generated) for r in wave)

    # -- Magneton audit --------------------------------------------------------
    def energy_report(self, *, prompt_len: int = 32, session=None):
        """Differential energy audit of this engine's decode step against the
        all-position-logits wasteful twin (hf-38977) — the profiler as a
        serving feature.

        Runs on the Session/artifact API: pass a store-backed
        :class:`repro.core.session.Session` to persist the decode-step
        capture and make repeated audits of an unchanged engine cache hits.
        """
        from repro.core.session import Session
        cfg = self.cfg
        B = self.ecfg.batch_size
        key = jax.random.key(0)
        tokens = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab_size)
        _, caches = self._prefill(self.params, tokens, None)

        def efficient(tok):
            logits, _ = tf.decode_step(cfg, self.params, caches, tok,
                                       jnp.int32(prompt_len))
            return logits.astype(jnp.float32)

        def wasteful(tok):
            # recompute the hidden for the last position but pay an
            # all-positions LM head (vocab x prompt_len redundant logits)
            logits, _ = tf.decode_step(cfg, self.params, caches, tok,
                                       jnp.int32(prompt_len))
            pad = jnp.broadcast_to(logits, (B, prompt_len, cfg.vocab_size))
            return pad[:, -1:, :].astype(jnp.float32)

        tok = jnp.zeros((B, 1), jnp.int32)
        session = session or Session()
        art_waste = session.capture(wasteful, (tok,), name="lmhead-all")
        art_eff = session.capture(efficient, (tok,), name="lmhead-last")
        return session.compare(art_waste, art_eff)

    def audit(self, *, prompt_len: int = 32, session=None,
              timeout_s: float | None = None):
        """Error-bounded :meth:`energy_report`: never raises, never hangs.

        Runs the audit on a watchdog daemon thread with a wall-clock budget
        (``timeout_s``, default ``ecfg.audit_timeout_s``).  Returns the
        :class:`~repro.core.report.Report` on success, ``None`` on any
        failure/timeout/open-breaker — serving always continues.  Health is
        tracked in ``stats``: after ``ecfg.audit_breaker_threshold``
        consecutive failures the circuit breaker opens and later calls are
        counted as ``audit_skipped`` without running anything, until
        :meth:`reset_audit_breaker`.
        """
        if self.stats["audit_breaker_open"]:
            self.stats["audit_skipped"] += 1
            return None
        return self._bounded_audit(
            lambda: self.energy_report(prompt_len=prompt_len,
                                       session=session),
            timeout_s=timeout_s)

    def _bounded_audit(self, thunk: Callable[[], Any], *,
                       timeout_s: float | None = None):
        """The shared watchdog/breaker boundary: run one audit thunk (an
        energy report or a sampled live audit) with a wall-clock budget,
        absorbing every failure into the health counters."""
        self.stats["audit_calls"] += 1
        budget = timeout_s if timeout_s is not None \
            else self.ecfg.audit_timeout_s
        box: dict[str, Any] = {}

        def run():
            try:
                box["result"] = thunk()
            except BaseException as e:        # incl. SimulatedCrash in tests
                box["error"] = e

        # daemon watchdog: a hung audit (dead store mount, wedged compile)
        # is abandoned at the deadline and must not block shutdown either
        t = threading.Thread(target=run, name="magneton-audit", daemon=True)
        t.start()
        t.join(budget)
        if t.is_alive():
            self.stats["audit_timeouts"] += 1
            self._audit_failed(f"audit exceeded {budget:g}s watchdog budget")
            return None
        if "error" in box:
            self._audit_failed(f"{type(box['error']).__name__}: "
                               f"{box['error']}")
            return None
        result = box.get("result")
        self.stats["audit_ok"] += 1
        self.stats["audit_consecutive_failures"] = 0
        if result is not None and (getattr(result, "is_degraded", False)
                                   or getattr(result, "degraded", False)):
            self.stats["audit_degraded"] += 1
        return result

    def _audit_failed(self, reason: str) -> None:
        self.stats["audit_failures"] += 1
        self.stats["audit_consecutive_failures"] += 1
        self.stats["audit_last_error"] = reason
        if (self.stats["audit_consecutive_failures"]
                >= self.ecfg.audit_breaker_threshold):
            self.stats["audit_breaker_open"] = True

    def reset_audit_breaker(self) -> None:
        """Re-arm auditing after the underlying fault has been fixed."""
        self.stats["audit_breaker_open"] = False
        self.stats["audit_consecutive_failures"] = 0

    # -- always-on sampled auditing (repro.audit, docs/serving.md) ------------
    def _audit_fingerprint(self) -> str:
        """Identity of the audited configuration: model + engine knobs.

        The demo decode mutation (``audit_mutate_decode``) is deliberately
        NOT part of it: a mutated engine must compare against the healthy
        fleet golden and alarm — not elect a golden of its own.
        """
        ident = {"arch": self.cfg.name, "batch_size": self.ecfg.batch_size,
                 "max_len": self.ecfg.max_len,
                 "attn_impl": self.ecfg.attn_impl}
        return hashlib.sha256(
            json.dumps(ident, sort_keys=True).encode()).hexdigest()[:16]

    def _audit_probe(self, rc: RequestClass):
        """Canonical seeded probe for one request class: ``(fn, args,
        config)`` for ``Session.capture``.

        The probe inputs are derived from the class key alone, so every
        engine in a fleet captures the same content-addressed artifact for
        the same class under the same config — the property golden sharing
        and conditional-put convergence rest on.
        """
        cfg = self.cfg
        seed = int.from_bytes(
            hashlib.sha256(rc.key.encode()).digest()[:4], "big")
        rng = np.random.default_rng(seed)
        B = max(1, min(rc.probe_batch, self.ecfg.batch_size))
        L = max(1, min(rc.probe_seq_len, self.ecfg.max_len - 1))
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, L)), jnp.int32)
        img = None
        if cfg.family == "vlm":
            img = jnp.zeros((B, cfg.num_image_tokens, cfg.d_model),
                            jnp.dtype(cfg.dtype))
        config = {"class": rc.key, "arch": cfg.name,
                  "attn_impl": self.ecfg.attn_impl}

        if rc.phase == "prefill":
            def prefill_probe(toks):
                logits, _ = self._prefill_fn(self.params, toks, img)
                return logits.astype(jnp.float32)
            return prefill_probe, (tokens,), config

        _, caches = self._prefill(self.params, tokens, img)

        def decode_probe(tok):
            logits, _ = tf.decode_step(cfg, self.params, caches, tok,
                                       jnp.int32(L))
            return logits.astype(jnp.float32)

        fn = decode_probe
        tok = jnp.zeros((B, 1), jnp.int32)
        if self.ecfg.audit_mutate_decode:
            from repro.testing.mutate import MUTATIONS, make_mutant
            mutation = MUTATIONS[self.ecfg.audit_mutate_decode]()
            fn, _sites = make_mutant(decode_probe, mutation, (tok,),
                                     name=f"decode__{mutation.name}")
        return fn, (tok,), config

    def _observe_audit(self, phase: str, batch: int, seq_len: int, *,
                       latency_s: float | None = None) -> None:
        """Feed one engine step to the sampler; run a sampled audit through
        the watchdog/breaker boundary when the policy fires."""
        if self.auditor is None:
            return
        rc, dec = self.auditor.observe(phase, batch, seq_len,
                                       latency_s=latency_s)
        if not dec.sample:
            return
        if self.stats["audit_breaker_open"]:
            self.stats["audit_skipped"] += 1
            return
        self.stats["audit_sampled"] += 1
        self._bounded_audit(
            lambda: self.auditor.sample(rc, dec.reason, latency_s=latency_s))
        self.stats["audit_alarms"] = self.auditor.log.alarm_count()

    def health(self) -> dict[str, Any]:
        """JSON-serializable service health: engine identity, the audit
        error-boundary state, and the live-audit summary.  Round-trips
        through ``json.dumps``/``json.loads`` unchanged — it is what a
        ``/healthz`` endpoint or the fleet dashboard would serve."""
        return {"engine_id": self.engine_id,
                "arch": self.cfg.name,
                "batch_size": self.ecfg.batch_size,
                "max_len": self.ecfg.max_len,
                "attn_impl": self.ecfg.attn_impl,
                "store": self.ecfg.store,
                "audit_breaker_open": self.stats["audit_breaker_open"],
                "audit_last_error": self.stats.get("audit_last_error"),
                "stats": dict(self.stats),
                "audit": (self.auditor.summary()
                          if self.auditor is not None else None)}
