"""Deterministic, restart-safe synthetic data pipeline.

The pipeline is a pure function of (seed, step): a restarted job resumes
from any step with bit-identical batches and *no* data replay/skip logic
beyond setting the step counter — the fault-tolerance property the
checkpoint manager relies on.  Sharded hosts draw disjoint slices of the
global batch by host index, so the global batch is identical regardless of
host count (elastic scaling keeps the data order stable).

The token stream is a deterministic mixture (zipf-ish unigram + short
repeated motifs) — enough structure that a ~100M model's loss visibly drops
within a few hundred steps (examples/train_demo.py).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    num_motifs: int = 512


class SyntheticLM:
    """Deterministic synthetic LM token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif table: short token patterns the model can learn
        self.motifs = rng.integers(
            0, cfg.vocab_size, size=(cfg.num_motifs, cfg.motif_len),
            dtype=np.int32)
        # zipf-ish unigram distribution
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def batch(self, step: int, *, host_index: int = 0,
              host_count: int = 1) -> dict[str, np.ndarray]:
        """Global batch for ``step`` (host slice if host_count > 1)."""
        cfg = self.cfg
        assert cfg.global_batch % host_count == 0
        per_host = cfg.global_batch // host_count
        rows = []
        base = step * cfg.global_batch + host_index * per_host
        for r in range(per_host):
            rows.append(self._row(base + r))
        tokens = np.stack(rows)                       # (B, S+1)
        return {"tokens": tokens[:, :-1].astype(np.int32),
                "labels": tokens[:, 1:].astype(np.int32)}

    def _row(self, row_id: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 32) ^ row_id)
        out = np.empty(cfg.seq_len + 1, dtype=np.int64)
        i = 0
        while i < cfg.seq_len + 1:
            if rng.random() < 0.5:                    # motif insertion
                m = self.motifs[rng.integers(cfg.num_motifs)]
                n = min(len(m), cfg.seq_len + 1 - i)
                out[i:i + n] = m[:n]
                i += n
            else:                                     # unigram noise
                n = min(int(rng.integers(4, 32)), cfg.seq_len + 1 - i)
                out[i:i + n] = rng.choice(cfg.vocab_size, size=n,
                                          p=self.unigram)
                i += n
        return out


def make_batch_fn(model_cfg: ModelConfig, shape: ShapeConfig, *,
                  seed: int = 0, batch_override: int | None = None):
    """Returns ``batch(step) -> dict`` matching the model's input schema."""
    gb = batch_override or shape.global_batch
    data = SyntheticLM(DataConfig(vocab_size=model_cfg.vocab_size,
                                  seq_len=shape.seq_len,
                                  global_batch=gb, seed=seed))

    def batch_fn(step: int) -> dict[str, np.ndarray]:
        b = data.batch(step)
        if model_cfg.family == "audio":
            # frontend stub: deterministic frame embeddings from the tokens
            rng = np.random.default_rng(seed ^ (step + 1))
            frames = rng.standard_normal(
                (gb, shape.seq_len, model_cfg.d_model)).astype(np.float32)
            return {"frames": frames, "labels": b["labels"]}
        if model_cfg.family == "vlm":
            rng = np.random.default_rng(seed ^ (step + 1))
            img = rng.standard_normal(
                (gb, model_cfg.num_image_tokens,
                 model_cfg.d_model)).astype(np.float32)
            return {**b, "image_embeds": img}
        return b

    return batch_fn
