"""Train-step factory: loss, gradient accumulation, optimizer update.

Microbatched gradient accumulation reduces activation memory and — because
the gradient all-reduce happens once after accumulation instead of per
microbatch — collective energy (the efficient twin of zoo case c9 /
pytorch-181115 dist.Join).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.layers import cross_entropy
from repro.train.optimizer import OptimizerConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool | str = True  # False | True (full) | 'dots' (save matmuls)
    attn_impl: str = "xla"
    z_loss: float = 1e-4
    accum_dtype: str = "float32"


def make_loss_fn(cfg: ModelConfig, mesh: Mesh | None,
                 tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        logits, aux = tf.forward(
            cfg, params,
            batch.get("tokens"),
            inputs_embeds=batch.get("frames"),
            image_embeds=batch.get("image_embeds"),
            mesh=mesh, remat=tcfg.remat, attn_impl=tcfg.attn_impl)
        loss = cross_entropy(logits, batch["labels"], z_loss=tcfg.z_loss)
        total = loss + cfg.router_aux_loss * aux
        return total, {"loss": loss, "aux_loss": aux}
    return loss_fn


def _split_microbatches(batch: dict, m: int) -> dict:
    def sp(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by {m} microbatches"
        return x.reshape(m, b // m, *x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def make_train_step(cfg: ModelConfig, mesh: Mesh | None,
                    opt_cfg: OptimizerConfig,
                    tcfg: TrainConfig = TrainConfig()) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    loss_fn = make_loss_fn(cfg, mesh, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            mb = _split_microbatches(batch, tcfg.microbatches)
            acc_dt = jnp.dtype(tcfg.accum_dtype)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def body(acc, micro):
                (loss, metrics), g = grad_fn(params, micro)
                acc2 = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(acc_dt), acc, g)
                return acc2, (loss, metrics)

            grads, (losses, metricses) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree_util.tree_map(
                lambda g: (g / tcfg.microbatches), grads)
            metrics = jax.tree_util.tree_map(jnp.mean, metricses)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(params, grads,
                                                        opt_state, opt_cfg)
        metrics = {**metrics, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, mesh: Mesh | None,
                   tcfg: TrainConfig = TrainConfig()) -> Callable:
    loss_fn = make_loss_fn(cfg, mesh, tcfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return eval_step
