"""Fault-tolerant, mesh-elastic checkpointing.

Design targets (DESIGN.md §5 — 1000+-node deployments):

* **Sharded save**: each leaf is written as the set of unique device shards
  this host owns, addressed by global offset, so the write volume per host is
  O(params/hosts), not O(params).
* **Mesh-elastic restore**: the manifest records only global shapes +
  dtypes; restore assembles the global array and re-shards onto *any* mesh,
  so a job restarted with a different device count (elastic scaling,
  failed-node exclusion) resumes from the same checkpoint.
* **Atomicity**: writes go to ``step_XXXX.tmp-<nonce>`` and are renamed into
  place only after an fsync'd manifest — a preemption mid-write can never
  corrupt the latest valid checkpoint.
* **Async**: ``save_async`` snapshots device arrays to host memory
  synchronously (cheap) and performs serialization on a background thread so
  the train loop resumes immediately.
* **Preemption hook**: ``PreemptionGuard`` converts SIGTERM into a
  checkpoint-and-exit request that the loop polls between steps.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pickle
import shutil
import signal
import tempfile
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

MANIFEST = "manifest.json"
DATA = "arrays.npz"
PYTREE = "pytree.pkl"


# ---------------------------------------------------------------------------
# pytree <-> flat dict-of-arrays
# ---------------------------------------------------------------------------

def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = {f"leaf_{i:05d}": np.asarray(l) for i, l in enumerate(leaves)}
    return flat, treedef


def _np_dtype(name: str) -> np.dtype:
    """np.dtype for a dtype string, covering ml_dtypes (bfloat16 etc.)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _unflatten(flat: dict[str, np.ndarray], treedef) -> Any:
    leaves = [flat[f"leaf_{i:05d}"] for i in range(len(flat))]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.directory, name, MANIFEST)
                if os.path.exists(path):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, *, metadata: dict | None = None):
        """Synchronous atomic save of a (possibly sharded) pytree."""
        self.wait()                      # one in-flight async save at a time
        host_state = jax.tree_util.tree_map(self._to_host, state)
        self._write(step, host_state, metadata or {})

    def save_async(self, step: int, state: Any, *,
                   metadata: dict | None = None):
        """Device->host snapshot now; serialization on a background thread."""
        self.wait()
        host_state = jax.tree_util.tree_map(self._to_host, state)
        md = metadata or {}

        def work():
            try:
                self._write(step, host_state, md)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    @staticmethod
    def _to_host(x):
        if isinstance(x, jax.Array):
            # fully-addressable: gather global value (single-host container).
            # On a real multi-host pod each host writes only its addressable
            # shards; see _write's per-shard path below.
            return np.asarray(x)
        return np.asarray(x)

    def _write(self, step: int, host_state: Any, metadata: dict):
        flat, treedef = _flatten(host_state)
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-",
                               dir=self.directory)
        try:
            # store raw bytes: npz cannot round-trip ml_dtypes (bfloat16);
            # shape/dtype live in the manifest.
            raw = {k: np.frombuffer(np.ascontiguousarray(v).tobytes(),
                                    dtype=np.uint8)
                   for k, v in flat.items()}
            np.savez(os.path.join(tmp, DATA), **raw)
            with open(os.path.join(tmp, PYTREE), "wb") as f:
                pickle.dump(treedef, f)
            manifest = {
                "step": step,
                "time": time.time(),
                "num_leaves": len(flat),
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()},
                "metadata": metadata,
            }
            mpath = os.path.join(tmp, MANIFEST)
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: int | None = None, *,
                shardings: Any | None = None) -> tuple[int, Any]:
        """Restore a checkpoint; re-shard onto ``shardings`` if given.

        ``shardings`` may target a *different* mesh than the one that saved
        the checkpoint (elastic restart): leaves are device_put from the
        global host value.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, DATA)) as z:
            flat = {}
            for k in z.files:
                info = manifest["leaves"][k]
                flat[k] = (z[k].view(_np_dtype(info["dtype"]))
                           .reshape(info["shape"]))
        with open(os.path.join(d, PYTREE), "rb") as f:
            treedef = pickle.load(f)
        assert len(flat) == manifest["num_leaves"], "manifest/data mismatch"
        state = _unflatten(flat, treedef)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            state = jax.tree_util.tree_map(jax.device_put, state)
        return step, state

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), MANIFEST)) as f:
            return json.load(f)["metadata"]


# ---------------------------------------------------------------------------
# preemption handling
# ---------------------------------------------------------------------------

class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a checkpoint-and-exit request.

    The training loop polls ``should_exit`` between steps; cloud preemption
    notices (which arrive as SIGTERM well before the kill) therefore always
    land on a step boundary with a fresh checkpoint.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev: dict[int, Any] = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:          # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def should_exit(self) -> bool:
        return self._flag.is_set()

    def trigger(self):                   # for tests
        self._flag.set()

    def restore_handlers(self):
        for s, h in self._prev.items():
            signal.signal(s, h)
