"""The training loop: sharded step, checkpoint/restart, straggler watch,
and the Magneton energy audit as a first-class feature.

``run_training`` is what launch/train.py drives.  It is deliberately plain:
every fault-tolerance behaviour (resume, preemption checkpoint, straggler
flagging) is observable and unit-tested (tests/test_train_loop.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.sharding.rules import GLOBAL_RULES
from repro.train.checkpoint import CheckpointManager, PreemptionGuard
from repro.train.data import make_batch_fn
from repro.train.optimizer import (OptimizerConfig, abstract_opt_state,
                                   init_opt_state, opt_state_shardings)
from repro.train.straggler import StragglerMonitor
from repro.train.train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    num_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    async_checkpoint: bool = True
    seed: int = 0


def batch_shardings(mesh: Mesh | None, batch: dict) -> dict | None:
    if mesh is None:
        return None
    return {k: GLOBAL_RULES.sharding(mesh, ("batch",) + (None,) * (v.ndim - 1),
                                     v.shape)
            for k, v in batch.items()}


def run_training(cfg: ModelConfig, shape: ShapeConfig, *,
                 mesh: Mesh | None = None,
                 opt_cfg: OptimizerConfig = OptimizerConfig(),
                 tcfg: TrainConfig = TrainConfig(),
                 loop: LoopConfig = LoopConfig(),
                 batch_override: int | None = None,
                 guard: PreemptionGuard | None = None,
                 on_step: Callable[[int, dict], None] | None = None) -> dict:
    """Train; resume from the latest checkpoint in loop.checkpoint_dir."""
    mgr = CheckpointManager(loop.checkpoint_dir)
    monitor = StragglerMonitor()
    batch_fn = make_batch_fn(cfg, shape, seed=loop.seed,
                             batch_override=batch_override)

    # --- state init or restore --------------------------------------------
    start = mgr.latest_step()
    if start is None:
        key = jax.random.key(loop.seed)
        params = tf.model_init(cfg, key)
        opt_state = init_opt_state(params, opt_cfg)
        if mesh is not None:
            pshard = tf.model_param_shardings(cfg, mesh)
            params = jax.tree_util.tree_map(jax.device_put, params, pshard)
            oshard = opt_state_shardings(pshard, opt_cfg, mesh)
            opt_state = jax.tree_util.tree_map(jax.device_put, opt_state,
                                               oshard)
        step0 = 0
    else:
        shardings = None
        if mesh is not None:
            pshard = tf.model_param_shardings(cfg, mesh)
            shardings = {"params": pshard,
                         "opt": opt_state_shardings(pshard, opt_cfg, mesh)}
        _, state = mgr.restore(start, shardings=shardings)
        params, opt_state = state["params"], state["opt"]
        step0 = start

    train_step = make_train_step(cfg, mesh, opt_cfg, tcfg)
    if mesh is not None:
        b0 = batch_fn(step0)
        jit_step = jax.jit(
            train_step,
            in_shardings=(tf.model_param_shardings(cfg, mesh),
                          opt_state_shardings(
                              tf.model_param_shardings(cfg, mesh),
                              opt_cfg, mesh),
                          batch_shardings(mesh, b0)),
            donate_argnums=(0, 1))
    else:
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    history: list[dict] = []
    exited_early = False
    for step in range(step0, loop.num_steps):
        t0 = time.time()
        batch = batch_fn(step)
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        metrics = {k: float(v) for k, v in
                   jax.tree_util.tree_map(np.asarray, metrics).items()}
        wall = time.time() - t0
        monitor.observe(wall, step=step)
        metrics.update(step=step, wall_time=wall)
        history.append(metrics)
        if on_step is not None:
            on_step(step, metrics)
        if loop.log_every and step % loop.log_every == 0:
            print(f"step {step:6d}  loss {metrics['loss']:.4f}  "
                  f"gnorm {metrics['grad_norm']:.3f}  {wall*1e3:.0f} ms")
        next_step = step + 1
        want_ckpt = (loop.checkpoint_every
                     and next_step % loop.checkpoint_every == 0)
        preempted = guard is not None and guard.should_exit
        if want_ckpt or preempted or next_step == loop.num_steps:
            state = {"params": params, "opt": opt_state}
            if loop.async_checkpoint and not preempted:
                mgr.save_async(next_step, state,
                               metadata={"loss": metrics["loss"]})
            else:
                mgr.save(next_step, state,
                         metadata={"loss": metrics["loss"],
                                   "preempted": preempted})
        if preempted:
            exited_early = True
            break

    mgr.wait()
    return {"history": history, "final_step": step + 1,
            "exited_early": exited_early,
            "straggler_events": monitor.events,
            "params": params, "opt_state": opt_state}
