"""AdamW with mixed-precision state and optional gradient compression.

State layout (pod-scale memory discipline, DESIGN.md §5): parameters live in
the model dtype (bf16), first/second moments in fp32 — 10 bytes/param, fully
sharded with the same PartitionSpecs as the parameters.  Updates are computed
in fp32 and cast back.

Gradient compression (an explicit distributed-optimization trick): grads can
be cast to bf16 before the data-parallel reduction, with fp32 error feedback
accumulated locally so the compression bias does not accumulate.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    compress_grads: bool = False   # bf16 reduction + fp32 error feedback


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: Any, cfg: OptimizerConfig) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree_util.tree_map(zeros32, params)
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def compress_decompress(grads: Any, err: Any):
    """bf16 compression with error feedback. Returns (compressed, new_err)."""
    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        gc = gf.astype(jnp.bfloat16)
        return gc, gf - gc.astype(jnp.float32)
    flat = jax.tree_util.tree_map(comp, grads, err)
    comp_g = jax.tree_util.tree_map(lambda t: t[0], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return comp_g, new_err


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: OptimizerConfig) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(cfg, step)
    new_state = dict(state)

    if cfg.compress_grads:
        grads, new_err = compress_decompress(grads, state["err"])
        new_state["err"] = new_err

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is3)
    new_state["m"] = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is3)
    new_state["v"] = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=is3)
    new_state["step"] = step + 1
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


def opt_state_shardings(param_shardings: Any, cfg: OptimizerConfig,
                        mesh) -> dict:
    """Optimizer-state shardings mirror the parameter shardings."""
    from jax.sharding import NamedSharding, PartitionSpec
    state = {
        "m": param_shardings,
        "v": param_shardings,
        "step": NamedSharding(mesh, PartitionSpec()),
    }
    if cfg.compress_grads:
        state["err"] = param_shardings
    return state


def abstract_opt_state(abstract_params: Any, cfg: OptimizerConfig) -> dict:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(f32, abstract_params),
        "v": jax.tree_util.tree_map(f32, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree_util.tree_map(f32, abstract_params)
    return state
