"""Straggler detection for pod-scale training.

Per-step wall times are tracked with an exponentially-weighted mean/variance;
a step (or, on a real multi-host deployment, a host's all-reduce arrival
time) whose z-score exceeds ``z_threshold`` for ``patience`` consecutive
steps flags a straggler.  The elastic-restart path (launch/train.py) consults
``exclusion_list`` to drop flagged hosts from the next mesh — the standard
mitigation at 1000+ nodes where a single slow HBM or thermally-throttled
chip gates every synchronous collective.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.05          # EWMA weight
    z_threshold: float = 3.0
    patience: int = 3
    warmup: int = 5              # ignore compile/cold-start steps

    def __post_init__(self):
        self._mean = 0.0
        self._var = 0.0
        self._n = 0
        self._consecutive: dict[str, int] = {}
        self.exclusion_list: list[str] = []
        self.events: list[dict] = []

    def observe(self, wall_time: float, *, source: str = "self",
                step: int | None = None) -> bool:
        """Record one step time; returns True if ``source`` is now flagged."""
        self._n += 1
        if self._n <= self.warmup:
            self._mean = wall_time
            return False
        delta = wall_time - self._mean
        self._mean += self.alpha * delta
        self._var = (1 - self.alpha) * (self._var + self.alpha * delta * delta)
        sd = math.sqrt(max(self._var, 1e-12))
        z = (wall_time - self._mean) / sd if sd > 0 else 0.0
        if z > self.z_threshold:
            c = self._consecutive.get(source, 0) + 1
            self._consecutive[source] = c
            if c >= self.patience and source not in self.exclusion_list:
                self.exclusion_list.append(source)
                self.events.append({"source": source, "step": step,
                                    "z": z, "wall_time": wall_time})
                return True
        else:
            self._consecutive[source] = 0
        return False

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def std(self) -> float:
        return math.sqrt(max(self._var, 0.0))
